"""Pallas TPU kernels for the SPION sparse-MHA hot spots.

sddmm / sparse_softmax / spmm: the paper-faithful 3-kernel pipeline
(cusparseSDDMM / warp softmax / cusparseSpMM adapted to BCSR + MXU tiles).
block_sparse_attn: beyond-paper fused flash-style kernel, differentiable
(custom VJP with Pallas dQ and dK/dV backward kernels).
ops: jit'd public wrappers; ref: pure-jnp oracles; dispatch: platform knobs
(interpret=None resolves to compiled-on-TPU / interpreter elsewhere).
"""
from repro.kernels.dispatch import default_interpret  # noqa: F401
from repro.kernels.ops import spion_attention_kernel  # noqa: F401
