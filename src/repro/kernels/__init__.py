"""Pallas TPU kernels for the SPION sparse-MHA hot spots.

sddmm / sparse_softmax / spmm: the paper-faithful 3-kernel pipeline
(cusparseSDDMM / warp softmax / cusparseSpMM adapted to BCSR + MXU tiles).
block_sparse_attn: beyond-paper fused flash-style kernel.
ops: jit'd public wrappers; ref: pure-jnp oracles.
"""
from repro.kernels.ops import spion_attention_kernel  # noqa: F401
