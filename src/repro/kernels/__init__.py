"""Pallas kernels for the SPION sparse-MHA hot spots.

block_sparse_attn: the single-pass fused flash-style kernel — the only
production path — differentiable (custom VJP with Pallas dQ and dK/dV
backward kernels) and double-buffered (DMA ring over the BCSR-indexed
K/V fetch). The paper's 3-kernel SDDMM / sparse-softmax / SpMM pipeline
survives solely as the pure-jnp oracle in ref.py (parity tests, Fig. 6).
ops: jit'd public wrappers; dispatch: platform knobs (interpret=None
resolves to compiled on TPU/GPU, interpreter elsewhere) + the hashable
KernelConfig; autotune: per-pattern config sweep with a persistent
on-disk cache (SPION_AUTOTUNE_DIR); sharded: the shard_map wrapper.
"""
from repro.kernels.dispatch import (KernelConfig,  # noqa: F401
                                    default_interpret)
from repro.kernels.ops import spion_attention_kernel  # noqa: F401
