"""Fused-kernel autotuner with a persistent on-disk config cache.

The compiled kernel lane (DESIGN.md §15) has real tuning freedom — the DMA
pipeline depth of the BCSR-indexed K/V fetch, the Mosaic grid dimension
semantics, Triton's num_warps/num_stages — and the best point depends on
the sparsity pattern (how many column blocks a row streams), the tile
shape, the dtype and the backend. This module sweeps a bounded candidate
set per (pattern-digest, table-shape, dtype, backend), times each with a
warmup-discarded min-of-reps, and persists the winner as one small JSON
file per key under `SPION_AUTOTUNE_DIR` (default ~/.cache/spion/autotune).

The cache is consulted — a pure lookup, never a sweep — when a
`SparseAttentionExec` is constructed with concrete tables, so serving and
training hit tuned configs without retracing: the config rides the exec's
static pytree aux, and jit keys the trace on it exactly once.

Correctness contract: a config may only ever change SPEED. Every swept
candidate's output is checked bitwise against the default config's before
it is eligible to win, and a corrupted / stale / unparseable cache entry
falls back to the default config with a loud warning — never a crash,
never a silently different result (tests/test_autotune.py).

Keys: `pattern_digest` hashes the BCSR table payload (col_idx/nvalid and,
when plan-built, row_idx/nvalid_t) plus the block size, so two phases with
the same geometry but different patterns tune independently; the table
shape (nrb, K, block), dtype and backend name complete the filename. The
digest is the same notion of pattern identity as core.spion.plan_digest,
restricted to the kernel-visible arrays.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dispatch import (DEFAULT_CONFIG, KernelConfig,
                                    compiled_backend, default_interpret)

_VERSION = 1
_ENV_DIR = "SPION_AUTOTUNE_DIR"
_ENV_ENABLE = "SPION_AUTOTUNE"
# same key order as core.sparse_attention.PLAN_TABLE_KEYS (not imported to
# keep this module usable on bare tables dicts without the core package)
_TABLE_KEYS = ("col_idx", "nvalid", "row_idx", "nvalid_t")


def enabled() -> bool:
    return os.environ.get(_ENV_ENABLE, "1") not in ("0", "false", "off")


def cache_dir() -> str:
    return os.environ.get(
        _ENV_DIR, os.path.join(os.path.expanduser("~"), ".cache", "spion",
                               "autotune"))


def pattern_digest(tables, block) -> str:
    """sha256 over the kernel-visible table payload + block size."""
    h = hashlib.sha256()
    h.update(f"block={int(block)}".encode())
    for key in _TABLE_KEYS:
        val = tables.get(key) if hasattr(tables, "get") else None
        if val is None:
            continue
        arr = np.asarray(val)
        h.update(f"|{key}:{arr.dtype}:{arr.shape}:".encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def _shape_sig(tables, block) -> str:
    col = np.asarray(tables["col_idx"])
    nrb, k = int(col.shape[-2]), int(col.shape[-1])
    return f"nrb{nrb}_k{k}_b{int(block)}"


def cache_path(digest: str, shape_sig: str, dtype, backend: str) -> str:
    name = f"{digest[:16]}__{shape_sig}__{jnp.dtype(dtype).name}__{backend}"
    return os.path.join(cache_dir(), name + ".json")


def _backend_name() -> str:
    return compiled_backend() or "interpret"


# ---------------------------------------------------------------------------
# cache IO (loud fallback on anything malformed)
# ---------------------------------------------------------------------------

def load_entry(path: str) -> dict | None:
    """Parse + validate one cache entry; None (with a warning) when bad."""
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            entry = json.load(f)
        if not isinstance(entry, dict):
            raise ValueError(f"entry is {type(entry).__name__}, not an object")
        if entry.get("version") != _VERSION:
            raise ValueError(f"cache version {entry.get('version')!r} != "
                             f"current {_VERSION} (stale entry)")
        entry["config"] = KernelConfig.from_json(entry["config"])
        return entry
    except (OSError, ValueError, TypeError, KeyError,
            json.JSONDecodeError) as e:
        warnings.warn(
            f"spion autotune: ignoring unusable cache entry {path} ({e}); "
            f"falling back to the default kernel config", stacklevel=2)
        return None


def lookup(tables, block, *, dtype=jnp.float32) -> KernelConfig | None:
    """Pure cache lookup (no sweep). None on miss / disabled / bad entry."""
    if not enabled():
        return None
    path = cache_path(pattern_digest(tables, block),
                      _shape_sig(tables, block), dtype, _backend_name())
    entry = load_entry(path)
    return None if entry is None else entry["config"]


def store(tables, block, config: KernelConfig, *, dtype=jnp.float32,
          best_us: float | None = None, swept: int = 0) -> str:
    path = cache_path(pattern_digest(tables, block),
                      _shape_sig(tables, block), dtype, _backend_name())
    os.makedirs(os.path.dirname(path), exist_ok=True)
    entry = {"version": _VERSION, "backend": _backend_name(),
             "dtype": jnp.dtype(dtype).name,
             "shape_sig": _shape_sig(tables, block),
             "config": config.to_json(), "best_us": best_us, "swept": swept}
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(entry, f, indent=1)
    os.replace(tmp, path)
    return path


# ---------------------------------------------------------------------------
# candidate sweep
# ---------------------------------------------------------------------------

def candidates(backend: str | None = None) -> list[KernelConfig]:
    """Bounded sweep set per backend (a handful, not a grid explosion)."""
    backend = _backend_name() if backend is None else backend
    if backend == "tpu":
        return [KernelConfig(depth=d, dimension_semantics=s)
                for d in (1, 2, 3)
                for s in (None, ("arbitrary", "arbitrary", "arbitrary"))]
    if backend == "gpu":
        return [KernelConfig(depth=d, num_warps=w, num_stages=st)
                for d in (1, 2) for w in (4, 8) for st in (2, 3)]
    # interpreter hosts still sweep the pipeline depth: the lane mechanics
    # (tune -> cache -> dispatch) must run end-to-end on CPU CI
    return [KernelConfig(depth=d) for d in (1, 2, 3)]


def _time_us(fn, *args, reps: int = 3, warmup: int = 1) -> float:
    """min-of-reps wall time in us; warmup iterations are discarded."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def tune(tables, block, *, heads: int = 1, group: int = 1, head_dim: int = 64,
         dtype=jnp.float32, causal: bool = False, sliding_window=None,
         reps: int = 3, interpret=None, write_cache: bool = True):
    """Sweep the candidate set on synthetic inputs shaped by the tables.

    Returns (best_config, report). The report lists every candidate's
    min-of-reps time and whether its output matched the default config's
    bitwise (mismatching candidates are disqualified — the cache must
    never change results). The winner is persisted unless
    write_cache=False."""
    from repro.kernels.block_sparse_attn import fused_block_sparse_attention

    col = jnp.maximum(jnp.asarray(tables["col_idx"]), 0).astype(jnp.int32)
    nvalid = jnp.asarray(tables["nvalid"]).astype(jnp.int32)
    if col.ndim == 3:        # stacked (Ly, nrb, K): tune on layer 0
        col, nvalid = col[0], nvalid[0]
    nrb = col.shape[0]
    ncb = max(nrb, int(np.asarray(col).max(initial=0)) + 1)
    interp = default_interpret(interpret)

    key = jax.random.key(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (heads, group, nrb * block, head_dim), dtype)
    k = jax.random.normal(kk, (heads, ncb * block, head_dim), dtype)
    v = jax.random.normal(kv, (heads, ncb * block, head_dim), dtype)

    def run(config):
        fn = jax.jit(lambda q, k, v: fused_block_sparse_attention(
            q, k, v, col, nvalid, block=block, causal=causal,
            sliding_window=sliding_window, interpret=interp, config=config))
        return fn, np.asarray(fn(q, k, v))

    base_fn, base_out = run(DEFAULT_CONFIG)
    report = []
    best, best_us = DEFAULT_CONFIG, _time_us(base_fn, q, k, v, reps=reps)
    report.append({"config": DEFAULT_CONFIG, "us": best_us, "bitwise": True})
    for cand in candidates():
        if cand == DEFAULT_CONFIG:
            continue
        fn, out = run(cand)
        bitwise = bool(np.array_equal(out, base_out))
        us = _time_us(fn, q, k, v, reps=reps)
        report.append({"config": cand, "us": us, "bitwise": bitwise})
        if not bitwise:
            warnings.warn(
                f"spion autotune: candidate {cand} changed kernel output "
                f"bitwise — disqualified", stacklevel=2)
            continue
        if us < best_us:
            best, best_us = cand, us
    if write_cache:
        store(tables, block, best, dtype=dtype, best_us=best_us,
              swept=len(report))
    return best, report


def tune_plan(plan, **kw):
    """`tune` on a core.sparse_attention.SparsityPlan."""
    return tune(plan.tables, plan.tables["block"], **kw)


def describe(config: KernelConfig | None) -> str:
    if config is None:
        return "default"
    parts = [f"depth={config.depth}"]
    for f in dataclasses.fields(config):
        val = getattr(config, f.name)
        if f.name != "depth" and val is not None:
            parts.append(f"{f.name}={val}")
    return ",".join(parts)
