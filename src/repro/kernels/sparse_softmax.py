"""Pallas TPU sparse softmax over BCSR block-rows (paper Alg. 6 on TPU).

GPU version: one warp per row, warp-shuffle reductions. TPU version: one grid
step per (N, row-block); the K active (B x B) tiles of that block-row sit in
VMEM at once and the row reduction is a vectorised max/sum over the (K*B)
lane axis — the VMEM-tile analogue of the warp reduction.

Faithful correction (Alg. 6 line 15): pruned positions contribute
exp(0 - max) each; row_total is L (encoder) or min(i+1, window) (causal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dispatch import default_interpret


def _kernel(col_ref, nvalid_ref, s_ref, o_ref, *, block, K, seq_len,
            causal, sliding_window):
    r = pl.program_id(1)
    s = s_ref[0, 0]                              # (K, B, B) fp32
    flat = jnp.moveaxis(s, 0, 1).reshape(block, K * block)
    neg = jnp.isneginf(flat)
    mx = jnp.maximum(jnp.max(flat, -1, keepdims=True), -1e30)
    ex = jnp.where(neg, 0.0, jnp.exp(flat - mx))
    denom = jnp.sum(ex, -1, keepdims=True)
    stored = jnp.sum((~neg).astype(jnp.float32), -1, keepdims=True)
    rows = r * block + jax.lax.broadcasted_iota(jnp.int32, (block, 1), 0)
    if causal:
        rt = (rows + 1).astype(jnp.float32)
        if sliding_window is not None:
            rt = jnp.minimum(rt, float(sliding_window))
    else:
        rt = jnp.full((block, 1), float(seq_len))
    denom = denom + jnp.maximum(rt - stored, 0.0) * jnp.exp(-mx)
    p = ex / denom
    o_ref[0, 0] = jnp.moveaxis(p.reshape(block, K, block), 1, 0)


def sparse_softmax(s_blocks, col_idx, nvalid, *, block, seq_len, causal=False,
                   sliding_window=None, interpret=None):
    """s_blocks (N, nrb, K, B, B) fp32 (-inf masked) -> probs, same shape.
    interpret=None resolves from the platform (compiled on TPU)."""
    interpret = default_interpret(interpret)
    N, nrb, K = s_blocks.shape[:3]
    kern = functools.partial(_kernel, block=block, K=K, seq_len=seq_len,
                             causal=causal, sliding_window=sliding_window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(N, nrb),
        in_specs=[pl.BlockSpec((1, 1, K, block, block),
                               lambda n, r, col, nv: (n, r, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, K, block, block),
                               lambda n, r, col, nv: (n, r, 0, 0, 0)),
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(s_blocks.shape, jnp.float32),
        interpret=interpret,
    )(col_idx, nvalid, s_blocks)
