"""Fused block-sparse flash attention — the BEYOND-PAPER kernel, now
differentiable end-to-end (jax.custom_vjp with Pallas forward AND backward).

Forward: one kernel replaces the paper's SDDMM -> sparse softmax -> SpMM
pipeline: for each (batch*kv-head, q-head-in-group, row-block), the K active
KV tiles stream through VMEM with running (max, sum, acc) flash statistics.
S^r and S^s never touch HBM — this is the TPU-native realisation of the
paper's data-locality argument (DESIGN.md §2), and it removes the
O(nnz * B^2) intermediate traffic the faithful pipeline pays. The sparse
softmax zero-correction (Alg. 6 line 15) is applied to the final denominator,
so the kernel is bit-compatible (up to fp assoc.) with the 3-kernel path.
Alongside the context it emits per-row log-sum-exp residuals
lse = m + log(denom); with the correction folded into denom, the softmax
probabilities reconstruct exactly as p = exp(s - lse) in the backward.

Backward (flash-attention-2 style, sparse):
  dQ    — same (N, G, nrb, K) row-block grid as the forward, streaming the
          active KV tiles and accumulating dq = scale * sum_c ds_c K_c.
  dK/dV — column-block grid over the TRANSPOSED BCSR tables: for
          column-block c, stream the row-blocks that reference it (and the
          G query heads sharing the kv head, innermost so the output tile is
          revisited consecutively) and accumulate dv += p^T dO,
          dk += scale * ds^T Q. The transposed tables come either from a
          host-built SparsityPlan (width KT* = true max column population,
          precomputed at phase transition) or, as a fallback, from the
          under-jit core.sparse_attention.bcsr_transpose at the always-safe
          width KT = nrb.
Both recompute p from (q, k, lse); ds = p * (dp - delta) with
delta = rowsum(dO * O). The Alg. 6 phantom positions carry constant score 0
and no value, so they alter only the forward normaliser — the standard
softmax cotangent identity still holds on the active pattern and gradients
match the dense reference there (tests/test_kernels.py).

Grids: fwd/dQ (N, G, nrb, K); dK/dV (N, ncb, KT, G) with KT = KT* under a
plan, KT = nrb on the fallback — innermost dims sequential; accumulators in
VMEM scratch.

Sequence-parallel operation (DESIGN.md §10): every kernel takes a third
scalar-prefetch input `offs = [row0, col0]` mapping shard-local block
indices to global ones (absolute row-block = local r + row0, absolute
column-block = storage col + col0). The causal / sliding-window tile masks
and the Alg. 6 zero-correction are computed in GLOBAL coordinates, so a
seq-shard running over its local Q rows and halo-extended K/V window gets
exactly the meshless math; the meshless path passes [0, 0] and is
bit-identical to before. `seq_len` (the non-causal zero-correction row
total) is overridable for the same reason — under a seq shard q.shape[2]
is the LOCAL row count, not the global sequence length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.sparse_attention import bcsr_transpose
from repro.distributed.sharding import current_mesh
from repro.kernels.dispatch import default_interpret, in_sharded_body

NEG = -1e30


def _tile_mask(r, col, block, causal, sliding_window):
    """(block, block) validity of the (row-block r, col-block col) tile."""
    qpos = r * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    kpos = col * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    ok = jnp.ones((block, block), bool)
    if causal:
        ok &= qpos >= kpos
    if sliding_window is not None:
        ok &= (qpos - kpos) < sliding_window
    return ok


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(col_ref, nvalid_ref, off_ref, q_ref, k_ref, v_ref, o_ref,
                lse_ref, m_ref, l_ref, acc_ref, *, block, hd, K, seq_len,
                scale, causal, sliding_window):
    r = pl.program_id(2)
    c = pl.program_id(3)

    @pl.when(c == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(c < nvalid_ref[r])
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)      # (B, hd)
        k = k_ref[0].astype(jnp.float32)         # (B, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        ok = _tile_mask(r + off_ref[0], col_ref[r, c] + off_ref[1], block,
                        causal, sliding_window)
        s = jnp.where(ok, s, NEG)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)                     # rescale factor
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, -1)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_new

    @pl.when(c == K - 1)
    def _finish():
        m = m_ref[:, 0]
        l = l_ref[:, 0]
        # Alg. 6 line 15 zero-correction: pruned positions count exp(0 - m).
        # Row positions are GLOBAL (off_ref[0] rebases seq-shard-local rows).
        rows = (r + off_ref[0]) * block + \
            jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
        if causal:
            rt = (rows + 1).astype(jnp.float32)
            if sliding_window is not None:
                rt = jnp.minimum(rt, float(sliding_window))
        else:
            rt = jnp.full((block,), float(seq_len))
        # stored counts come from the same masks; recompute per active tile
        stored = jnp.zeros((block,), jnp.float32)

        def count(i, acc):
            ok = _tile_mask(r + off_ref[0], col_ref[r, i] + off_ref[1], block,
                            causal, sliding_window)
            ok &= jnp.full((block, block), i < nvalid_ref[r])
            return acc + jnp.sum(ok.astype(jnp.float32), -1)

        stored = jax.lax.fori_loop(0, K, count, stored)
        denom = l + jnp.maximum(rt - stored, 0.0) * jnp.exp(-m)
        safe = jnp.where(denom == 0.0, 1.0, denom)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)
        # rows with truly empty denominators get lse=+inf -> p = 0 in bwd
        lse_ref[0, 0] = jnp.where(denom > 0.0, m + jnp.log(safe), jnp.inf)


def _zero_offsets():
    return jnp.zeros((2,), jnp.int32)


def _fused_forward(q, k, v, col_idx, nvalid, *, block, causal, sliding_window,
                   interpret, offsets=None, seq_len=None):
    """Returns (o (N, G, S, hd), lse (N, G, S) fp32). `S` is the local row
    count; `seq_len` (default S) is the GLOBAL sequence length used by the
    non-causal zero-correction, and `offsets` the [row0, col0] rebasing of
    local block indices to global ones (see module docstring)."""
    N, G, S, hd = q.shape
    nrb, K = col_idx.shape
    offsets = _zero_offsets() if offsets is None else offsets
    scale = 1.0 / np.sqrt(hd)
    kern = functools.partial(_fwd_kernel, block=block, hd=hd, K=K,
                             seq_len=S if seq_len is None else int(seq_len),
                             scale=scale, causal=causal,
                             sliding_window=sliding_window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(N, G, nrb, K),
        in_specs=[
            pl.BlockSpec((1, 1, block, hd),
                         lambda n, g, r, c, col, nv, off: (n, g, r, 0)),
            pl.BlockSpec((1, block, hd),
                         lambda n, g, r, c, col, nv, off: (n, col[r, c], 0)),
            pl.BlockSpec((1, block, hd),
                         lambda n, g, r, c, col, nv, off: (n, col[r, c], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block, hd),
                         lambda n, g, r, c, col, nv, off: (n, g, r, 0)),
            pl.BlockSpec((1, 1, block),
                         lambda n, g, r, c, col, nv, off: (n, g, r)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block, 1), jnp.float32),    # running max
            pltpu.VMEM((block, 1), jnp.float32),    # running sum
            pltpu.VMEM((block, hd), jnp.float32),   # output accumulator
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((N, G, S, hd), q.dtype),
                   jax.ShapeDtypeStruct((N, G, S), jnp.float32)],
        interpret=interpret,
    )(col_idx, nvalid, offsets, q, k, v)


# ---------------------------------------------------------------------------
# backward: dQ  (row-block grid, streams active KV tiles — forward's twin)
# ---------------------------------------------------------------------------

def _dq_kernel(col_ref, nvalid_ref, off_ref, q_ref, k_ref, v_ref, do_ref,
               lse_ref, delta_ref, dq_ref, acc_ref, *, block, K, scale,
               causal, sliding_window):
    r = pl.program_id(2)
    c = pl.program_id(3)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(c < nvalid_ref[r])
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)       # (B, hd)
        k = k_ref[0].astype(jnp.float32)          # (B, hd)
        v = v_ref[0].astype(jnp.float32)          # (B, hd)
        do = do_ref[0, 0].astype(jnp.float32)     # (B, hd)
        lse = lse_ref[0, 0]                       # (B,)
        delta = delta_ref[0, 0]                   # (B,)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        ok = _tile_mask(r + off_ref[0], col_ref[r, c] + off_ref[1], block,
                        causal, sliding_window)
        p = jnp.where(ok, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when(c == K - 1)
    def _finish():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _fused_dq(q, k, v, do, lse, delta, col_idx, nvalid, *, block, causal,
              sliding_window, interpret, offsets=None):
    N, G, S, hd = q.shape
    nrb, K = col_idx.shape
    offsets = _zero_offsets() if offsets is None else offsets
    scale = 1.0 / np.sqrt(hd)
    kern = functools.partial(_dq_kernel, block=block, K=K, scale=scale,
                             causal=causal, sliding_window=sliding_window)
    qspec = pl.BlockSpec((1, 1, block, hd),
                         lambda n, g, r, c, col, nv, off: (n, g, r, 0))
    kvspec = pl.BlockSpec((1, block, hd),
                          lambda n, g, r, c, col, nv, off: (n, col[r, c], 0))
    rowspec = pl.BlockSpec((1, 1, block),
                           lambda n, g, r, c, col, nv, off: (n, g, r))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(N, G, nrb, K),
        in_specs=[qspec, kvspec, kvspec, qspec, rowspec, rowspec],
        out_specs=qspec,
        scratch_shapes=[pltpu.VMEM((block, hd), jnp.float32)],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, G, S, hd), jnp.float32),
        interpret=interpret,
    )(col_idx, nvalid, offsets, q, k, v, do, lse, delta)


# ---------------------------------------------------------------------------
# backward: dK/dV  (column-block grid over the transposed BCSR tables)
# ---------------------------------------------------------------------------

def _dkv_kernel(row_ref, nvt_ref, off_ref, q_ref, k_ref, v_ref, do_ref,
                lse_ref, delta_ref, dk_ref, dv_ref, dk_acc, dv_acc, *, block,
                KT, G, scale, causal, sliding_window):
    c = pl.program_id(1)
    t = pl.program_id(2)
    g = pl.program_id(3)

    @pl.when((t == 0) & (g == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    @pl.when(t < nvt_ref[c])
    def _step():
        r = row_ref[c, t]
        q = q_ref[0, 0].astype(jnp.float32)       # (B, hd) rows of block r
        k = k_ref[0].astype(jnp.float32)          # (B, hd) column block c
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        ok = _tile_mask(r + off_ref[0], c + off_ref[1], block, causal,
                        sliding_window)
        p = jnp.where(ok, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        # contract the q-row axis: dv_c += p^T dO_r ; dk_c += scale ds^T Q_r
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when((t == KT - 1) & (g == G - 1))
    def _finish():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def _fused_dkv(q, k, v, do, lse, delta, row_idx, nvalid_t, *, block, causal,
               sliding_window, interpret, offsets=None):
    N, G, S, hd = q.shape
    Sk = k.shape[1]
    ncb, KT = row_idx.shape
    offsets = _zero_offsets() if offsets is None else offsets
    scale = 1.0 / np.sqrt(hd)
    kern = functools.partial(_dkv_kernel, block=block, KT=KT, G=G, scale=scale,
                             causal=causal, sliding_window=sliding_window)
    qspec = pl.BlockSpec((1, 1, block, hd),
                         lambda n, c, t, g, row, nvt, off: (n, g, row[c, t], 0))
    colspec = pl.BlockSpec((1, block, hd),
                           lambda n, c, t, g, row, nvt, off: (n, c, 0))
    rowspec = pl.BlockSpec((1, 1, block),
                           lambda n, c, t, g, row, nvt, off: (n, g, row[c, t]))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        # g innermost so every revisit of the (n, c) output tile is consecutive
        grid=(N, ncb, KT, G),
        in_specs=[qspec, colspec, colspec, qspec, rowspec, rowspec],
        out_specs=[colspec, colspec],
        scratch_shapes=[pltpu.VMEM((block, hd), jnp.float32),
                        pltpu.VMEM((block, hd), jnp.float32)],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((N, Sk, hd), jnp.float32),
                   jax.ShapeDtypeStruct((N, Sk, hd), jnp.float32)],
        interpret=interpret,
    )(row_idx, nvalid_t, offsets, q, k, v, do, lse, delta)


# ---------------------------------------------------------------------------
# custom VJP assembly
# ---------------------------------------------------------------------------

def _int_zero(x):
    """float0 cotangent for integer-dtype primal inputs (the BCSR tables)."""
    return np.zeros(x.shape, jax.dtypes.float0)


@functools.lru_cache(maxsize=None)
def _fused_op(block, causal, sliding_window, interpret, with_plan, seq_len):
    """One differentiable fused-attention op per static config (cached so the
    custom_vjp identity is stable across traces).

    with_plan=True takes precomputed transposed tables (row_idx, nvalid_t)
    as extra primal inputs — the host-built SparsityPlan path: the dK/dV
    grid width is row_idx.shape[1] = KT* (true max column population) and no
    bcsr_transpose runs under jit. with_plan=False is the fallback that
    rebuilds the transposed tables in every backward at width KT = nrb.

    Every op additionally takes the `offs = [row0, col0]` block-index
    rebasing as an int32 primal (float0 cotangent); seq_len=None means "use
    q.shape[2]" — both are [0,0]/None everywhere except inside a seq shard.
    """
    fwd_ = functools.partial(_fused_forward, block=block, causal=causal,
                             sliding_window=sliding_window,
                             interpret=interpret, seq_len=seq_len)

    def bwd_core(q, k, v, col_idx, nvalid, offs, o, lse, do, row_idx,
                 nvalid_t):
        """Shared backward body — both vjp variants differ only in where the
        transposed tables come from (plan residuals vs under-jit rebuild)."""
        delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), -1)
        dq = _fused_dq(q, k, v, do, lse, delta, col_idx, nvalid, block=block,
                       causal=causal, sliding_window=sliding_window,
                       interpret=interpret, offsets=offs)
        dk, dv = _fused_dkv(q, k, v, do, lse, delta, row_idx, nvalid_t,
                            block=block, causal=causal,
                            sliding_window=sliding_window, interpret=interpret,
                            offsets=offs)
        return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)

    if with_plan:
        @jax.custom_vjp
        def op(q, k, v, col_idx, nvalid, offs, row_idx, nvalid_t):
            return fwd_(q, k, v, col_idx, nvalid, offsets=offs)[0]

        def op_fwd(q, k, v, col_idx, nvalid, offs, row_idx, nvalid_t):
            o, lse = fwd_(q, k, v, col_idx, nvalid, offsets=offs)
            return o, (q, k, v, col_idx, nvalid, offs, row_idx, nvalid_t, o,
                       lse)

        def op_bwd(res, do):
            q, k, v, col_idx, nvalid, offs, row_idx, nvalid_t, o, lse = res
            dq, dk, dv = bwd_core(q, k, v, col_idx, nvalid, offs, o, lse, do,
                                  row_idx, nvalid_t)
            return (dq, dk, dv, _int_zero(col_idx), _int_zero(nvalid),
                    _int_zero(offs), _int_zero(row_idx), _int_zero(nvalid_t))

        op.defvjp(op_fwd, op_bwd)
        return op

    @jax.custom_vjp
    def op(q, k, v, col_idx, nvalid, offs):
        return fwd_(q, k, v, col_idx, nvalid, offsets=offs)[0]

    def op_fwd(q, k, v, col_idx, nvalid, offs):
        o, lse = fwd_(q, k, v, col_idx, nvalid, offsets=offs)
        return o, (q, k, v, col_idx, nvalid, offs, o, lse)

    def op_bwd(res, do):
        q, k, v, col_idx, nvalid, offs, o, lse = res
        row_idx, nvalid_t = bcsr_transpose(col_idx, nvalid,
                                           ncb=k.shape[1] // block)
        dq, dk, dv = bwd_core(q, k, v, col_idx, nvalid, offs, o, lse, do,
                              row_idx, nvalid_t)
        return dq, dk, dv, _int_zero(col_idx), _int_zero(nvalid), \
            _int_zero(offs)

    op.defvjp(op_fwd, op_bwd)
    return op


def fused_block_sparse_attention(q, k, v, col_idx, nvalid, *, block,
                                 causal=False, sliding_window=None,
                                 interpret=None, row_idx=None, nvalid_t=None,
                                 offsets=None, seq_len=None):
    """q (N, G, S, hd) — G query heads share each kv head; k, v (N, Sk, hd);
    col_idx (nrb, K) clamped, nvalid (nrb,). Returns (N, G, S, hd).

    Differentiable: jax.grad flows through Pallas dQ / dK/dV kernels (dK/dV
    sum over the G query heads of each kv head). `interpret=None` resolves
    from the platform (compiled on TPU, interpreter elsewhere).

    When a host-built SparsityPlan supplies `row_idx (ncb, KT*)` and
    `nvalid_t (ncb,)`, the dK/dV backward grid is (N, ncb, KT*, G) — sized
    to the measured pattern — and no bcsr_transpose runs under jit. Without
    them the backward falls back to the under-jit transpose at the
    always-safe width KT = ncb.

    Sequence-parallel callers (kernels/sharded.py seq mode) pass local
    tables, `offsets = [row0, col0]` (int32 (2,), the global block index of
    local Q row-block 0 and of K/V storage block 0) and the GLOBAL
    `seq_len`; Sk may then exceed S by the halo width. Meshless callers
    leave both at None (identical math to before).

    Single-shard op: under a multi-device mesh it must run inside the
    shard_map wrapper (kernels/sharded.py) — pallas_call has no GSPMD
    partitioning rule, so a bare call would be silently replicated on every
    device. That misuse fails loudly here instead.
    """
    mesh = current_mesh()
    if mesh is not None and mesh.size > 1 and not in_sharded_body():
        raise RuntimeError(
            f"fused_block_sparse_attention called under a multi-device mesh "
            f"{dict(mesh.shape)} outside the shard_map wrapper: pallas_call "
            f"has no GSPMD partitioning rule, so the kernel would run fully "
            f"replicated on every device. Route the call through "
            f"kernels.ops.spion_attention_kernel (mesh-aware) or "
            f"kernels.sharded.sharded_fused_attention, or use the jnp BCSR "
            f"path (cfg.spion.kernel='jnp').")
    op = _fused_op(int(block), bool(causal),
                   None if sliding_window is None else int(sliding_window),
                   default_interpret(interpret), row_idx is not None,
                   None if seq_len is None else int(seq_len))
    offs = _zero_offsets() if offsets is None else offsets.astype(jnp.int32)
    if row_idx is not None:
        return op(q, k, v, col_idx.astype(jnp.int32), nvalid.astype(jnp.int32),
                  offs, row_idx.astype(jnp.int32), nvalid_t.astype(jnp.int32))
    return op(q, k, v, col_idx.astype(jnp.int32), nvalid.astype(jnp.int32),
              offs)
