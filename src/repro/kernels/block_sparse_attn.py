"""Fused block-sparse flash attention — the BEYOND-PAPER kernel, now
differentiable end-to-end (jax.custom_vjp with Pallas forward AND backward)
and the ONLY production attention kernel (the paper's 3-kernel
SDDMM -> sparse softmax -> SpMM pipeline survives solely as the pure-jnp
oracle in kernels/ref.py — see DESIGN.md §15).

Forward: one kernel replaces the paper's three: for each
(batch*kv-head, q-head-in-group, row-block), the K active KV tiles stream
through VMEM with running (max, sum, acc) flash statistics. S^r and S^s
never touch HBM — this is the TPU-native realisation of the paper's
data-locality argument (DESIGN.md §2), and it removes the O(nnz * B^2)
intermediate traffic the faithful pipeline pays. The sparse softmax
zero-correction (Alg. 6 line 15) is applied to the final denominator, so
the kernel is bit-compatible (up to fp assoc.) with the reference
pipeline. Alongside the context it emits per-row log-sum-exp residuals
lse = m + log(denom); with the correction folded into denom, the softmax
probabilities reconstruct exactly as p = exp(s - lse) in the backward.

Double-buffered BCSR fetch (DESIGN.md §15): the gathered operands — K/V
tiles in the forward and dQ, Q/dO/lse/delta row slices in dK/dV — live in
HBM (`pltpu.ANY`) and are DMA'd into a `depth`-slot VMEM ring with
`pltpu.make_async_copy`, so the NEXT column block's fetch overlaps the
CURRENT block's matmul. Schedule per grid step (K = table width):
prologue starts DMAs 0..depth-2; loop iteration i first starts DMA
i+depth-1 into the slot iteration i-1 just drained, then waits DMA i
(slot i % depth) and computes. depth=1 degenerates to a synchronous
fetch; the depth (and the Mosaic/Triton lowering knobs) come from the
`KernelConfig` the autotuner picked (kernels/autotune.py). Entries past
`nvalid` fetch a (clamped, in-range) tile unconditionally and are masked
out of the flash update as exact no-ops — uniform DMA traffic keeps the
pipeline free of start/wait divergence.

Backward (flash-attention-2 style, sparse):
  dQ    — same (N, G, nrb) row-block grid as the forward, streaming the
          active KV tiles and accumulating dq = scale * sum_c ds_c K_c.
  dK/dV — column-block grid over the TRANSPOSED BCSR tables: for
          column-block c, stream the row-blocks that reference it (and the
          G query heads sharing the kv head, innermost so the output tile
          is revisited consecutively) and accumulate dv += p^T dO,
          dk += scale * ds^T Q. The transposed tables come either from a
          host-built SparsityPlan (width KT* = true max column population,
          precomputed at phase transition) or, as a fallback, from the
          under-jit core.sparse_attention.bcsr_transpose at the always-safe
          width KT = nrb.
Both recompute p from (q, k, lse); ds = p * (dp - delta) with
delta = rowsum(dO * O). The Alg. 6 phantom positions carry constant score 0
and no value, so they alter only the forward normaliser — the standard
softmax cotangent identity still holds on the active pattern and gradients
match the dense reference there (tests/test_kernels.py).

Grids: fwd/dQ (N, G, nrb) with the K streaming loop INSIDE each grid step
(that is what makes the DMA ring possible); dK/dV (N, ncb, G) with the KT
loop inside and g innermost-sequential for the scratch accumulators.

Sequence-parallel operation (DESIGN.md §10): every kernel takes a third
scalar-prefetch input `offs = [row0, col0]` mapping shard-local block
indices to global ones (absolute row-block = local r + row0, absolute
column-block = storage col + col0). The causal / sliding-window tile masks
and the Alg. 6 zero-correction are computed in GLOBAL coordinates, so a
seq-shard running over its local Q rows and halo-extended K/V window gets
exactly the meshless math; the meshless path passes [0, 0] and is
bit-identical to before. `seq_len` (the non-causal zero-correction row
total) is overridable for the same reason — under a seq shard q.shape[2]
is the LOCAL row count, not the global sequence length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.sparse_attention import bcsr_transpose
from repro.distributed.sharding import current_mesh
from repro.kernels.dispatch import (DEFAULT_CONFIG, KernelConfig,
                                    compiled_backend, default_interpret,
                                    in_sharded_body)

NEG = -1e30


def _tile_mask(r, col, block, causal, sliding_window):
    """(block, block) validity of the (row-block r, col-block col) tile."""
    qpos = r * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    kpos = col * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    ok = jnp.ones((block, block), bool)
    if causal:
        ok &= qpos >= kpos
    if sliding_window is not None:
        ok &= (qpos - kpos) < sliding_window
    return ok


def _depth(config, width):
    """Effective ring depth: never deeper than the streamed table width."""
    return max(1, min(int(config.depth), max(int(width), 1)))


def _compiler_params(config, interpret, default_semantics):
    """Backend-specific lowering knobs from the tuned KernelConfig.

    None in interpret mode (nothing lowers) and on unknown backends.
    Mosaic gets dimension_semantics — config's for the fwd/dQ grids,
    `default_semantics` verbatim where the grid has mandatory-sequential
    dims (dK/dV's innermost g). Triton gets num_warps / num_stages."""
    if interpret or config is None:
        return None
    backend = compiled_backend()
    if backend == "tpu":
        sem = default_semantics
        if config.dimension_semantics is not None and \
                default_semantics is not None and \
                "arbitrary" not in default_semantics:
            rank = len(default_semantics)
            sem = tuple(config.dimension_semantics)[:rank]
            sem += ("arbitrary",) * (rank - len(sem))
        if sem is None:
            return None
        return pltpu.TPUCompilerParams(dimension_semantics=sem)
    if backend == "gpu":
        from jax.experimental.pallas import triton as pltriton
        kw = {}
        if config.num_warps is not None:
            kw["num_warps"] = int(config.num_warps)
        if config.num_stages is not None:
            kw["num_stages"] = int(config.num_stages)
        return pltriton.TritonCompilerParams(**kw) if kw else None
    return None


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(col_ref, nvalid_ref, off_ref, q_ref, k_hbm, v_hbm, o_ref,
                lse_ref, kbuf, vbuf, ksem, vsem, *, block, hd, K, depth,
                seq_len, scale, causal, sliding_window):
    n = pl.program_id(0)
    r = pl.program_id(2)
    nv = nvalid_ref[r]

    def kv_copies(slot, i):
        c = col_ref[r, i]
        src = pl.ds(c * block, block)
        return (pltpu.make_async_copy(k_hbm.at[n, src, :], kbuf.at[slot],
                                      ksem.at[slot]),
                pltpu.make_async_copy(v_hbm.at[n, src, :], vbuf.at[slot],
                                      vsem.at[slot]))

    # prologue: fill the ring (depth-1 fetches in flight before compute)
    for j in range(min(depth - 1, K)):
        for cp in kv_copies(j, j):
            cp.start()

    q = q_ref[0, 0].astype(jnp.float32)          # (B, hd)

    def step(i, carry):
        m_prev, l_prev, acc = carry
        ahead = i + depth - 1

        @pl.when(ahead < K)
        def _prefetch():
            # the slot iteration i-1 just drained (= ahead % depth)
            for cp in kv_copies(jax.lax.rem(ahead, depth), ahead):
                cp.start()

        slot = jax.lax.rem(i, depth)
        for cp in kv_copies(slot, i):
            cp.wait()
        k = kbuf[slot].astype(jnp.float32)       # (B, hd)
        v = vbuf[slot].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        ok = _tile_mask(r + off_ref[0], col_ref[r, i] + off_ref[1], block,
                        causal, sliding_window)
        # entries past nvalid are fetched (uniform DMA schedule) but are
        # exact no-ops on the flash carry: s=NEG keeps m, alpha=exp(0)=1,
        # p=0 adds nothing to l or acc
        ok &= jnp.full((block, block), i < nv)
        s = jnp.where(ok, s, NEG)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)                     # rescale factor
        p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
        l_new = l_prev * alpha + jnp.sum(p, -1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m, l, acc = jax.lax.fori_loop(
        0, K, step, (jnp.full((block,), NEG, jnp.float32),
                     jnp.zeros((block,), jnp.float32),
                     jnp.zeros((block, hd), jnp.float32)))

    # Alg. 6 line 15 zero-correction: pruned positions count exp(0 - m).
    # Row positions are GLOBAL (off_ref[0] rebases seq-shard-local rows).
    rows = (r + off_ref[0]) * block + \
        jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    if causal:
        rt = (rows + 1).astype(jnp.float32)
        if sliding_window is not None:
            rt = jnp.minimum(rt, float(sliding_window))
    else:
        rt = jnp.full((block,), float(seq_len))
    # stored counts come from the same masks; recompute per active tile

    def count(i, acc_):
        ok = _tile_mask(r + off_ref[0], col_ref[r, i] + off_ref[1], block,
                        causal, sliding_window)
        ok &= jnp.full((block, block), i < nv)
        return acc_ + jnp.sum(ok.astype(jnp.float32), -1)

    stored = jax.lax.fori_loop(0, K, count, jnp.zeros((block,), jnp.float32))
    denom = l + jnp.maximum(rt - stored, 0.0) * jnp.exp(-m)
    safe = jnp.where(denom == 0.0, 1.0, denom)
    o_ref[0, 0] = (acc / safe[:, None]).astype(o_ref.dtype)
    # rows with truly empty denominators get lse=+inf -> p = 0 in bwd
    lse_ref[0, 0] = jnp.where(denom > 0.0, m + jnp.log(safe), jnp.inf)


def _zero_offsets():
    return jnp.zeros((2,), jnp.int32)


def _fused_forward(q, k, v, col_idx, nvalid, *, block, causal, sliding_window,
                   interpret, offsets=None, seq_len=None, config=None):
    """Returns (o (N, G, S, hd), lse (N, G, S) fp32). `S` is the local row
    count; `seq_len` (default S) is the GLOBAL sequence length used by the
    non-causal zero-correction, and `offsets` the [row0, col0] rebasing of
    local block indices to global ones (see module docstring)."""
    N, G, S, hd = q.shape
    nrb, K = col_idx.shape
    config = DEFAULT_CONFIG if config is None else config
    depth = _depth(config, K)
    offsets = _zero_offsets() if offsets is None else offsets
    scale = 1.0 / np.sqrt(hd)
    kern = functools.partial(_fwd_kernel, block=block, hd=hd, K=K,
                             depth=depth,
                             seq_len=S if seq_len is None else int(seq_len),
                             scale=scale, causal=causal,
                             sliding_window=sliding_window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(N, G, nrb),
        in_specs=[
            pl.BlockSpec((1, 1, block, hd),
                         lambda n, g, r, col, nv, off: (n, g, r, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # K stays in HBM: DMA ring
            pl.BlockSpec(memory_space=pltpu.ANY),   # V stays in HBM: DMA ring
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block, hd),
                         lambda n, g, r, col, nv, off: (n, g, r, 0)),
            pl.BlockSpec((1, 1, block),
                         lambda n, g, r, col, nv, off: (n, g, r)),
        ],
        scratch_shapes=[
            pltpu.VMEM((depth, block, hd), k.dtype),    # K tile ring
            pltpu.VMEM((depth, block, hd), v.dtype),    # V tile ring
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((N, G, S, hd), q.dtype),
                   jax.ShapeDtypeStruct((N, G, S), jnp.float32)],
        compiler_params=_compiler_params(config, interpret,
                                         ("parallel",) * 3),
        interpret=interpret,
    )(col_idx, nvalid, offsets, q, k, v)


# ---------------------------------------------------------------------------
# backward: dQ  (row-block grid, streams active KV tiles — forward's twin)
# ---------------------------------------------------------------------------

def _dq_kernel(col_ref, nvalid_ref, off_ref, q_ref, k_hbm, v_hbm, do_ref,
               lse_ref, delta_ref, dq_ref, kbuf, vbuf, ksem, vsem, *, block,
               hd, K, depth, scale, causal, sliding_window):
    n = pl.program_id(0)
    r = pl.program_id(2)
    nv = nvalid_ref[r]

    def kv_copies(slot, i):
        c = col_ref[r, i]
        src = pl.ds(c * block, block)
        return (pltpu.make_async_copy(k_hbm.at[n, src, :], kbuf.at[slot],
                                      ksem.at[slot]),
                pltpu.make_async_copy(v_hbm.at[n, src, :], vbuf.at[slot],
                                      vsem.at[slot]))

    for j in range(min(depth - 1, K)):
        for cp in kv_copies(j, j):
            cp.start()

    q = q_ref[0, 0].astype(jnp.float32)       # (B, hd)
    do = do_ref[0, 0].astype(jnp.float32)     # (B, hd)
    lse = lse_ref[0, 0]                       # (B,)
    delta = delta_ref[0, 0]                   # (B,)

    def step(i, acc):
        ahead = i + depth - 1

        @pl.when(ahead < K)
        def _prefetch():
            for cp in kv_copies(jax.lax.rem(ahead, depth), ahead):
                cp.start()

        slot = jax.lax.rem(i, depth)
        for cp in kv_copies(slot, i):
            cp.wait()
        k = kbuf[slot].astype(jnp.float32)    # (B, hd)
        v = vbuf[slot].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        ok = _tile_mask(r + off_ref[0], col_ref[r, i] + off_ref[1], block,
                        causal, sliding_window)
        ok &= jnp.full((block, block), i < nv)      # padded entries: ds = 0
        p = jnp.where(ok, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        return acc + jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    acc = jax.lax.fori_loop(0, K, step, jnp.zeros((block, hd), jnp.float32))
    dq_ref[0, 0] = acc.astype(dq_ref.dtype)


def _fused_dq(q, k, v, do, lse, delta, col_idx, nvalid, *, block, causal,
              sliding_window, interpret, offsets=None, config=None):
    N, G, S, hd = q.shape
    nrb, K = col_idx.shape
    config = DEFAULT_CONFIG if config is None else config
    depth = _depth(config, K)
    offsets = _zero_offsets() if offsets is None else offsets
    scale = 1.0 / np.sqrt(hd)
    kern = functools.partial(_dq_kernel, block=block, hd=hd, K=K, depth=depth,
                             scale=scale, causal=causal,
                             sliding_window=sliding_window)
    qspec = pl.BlockSpec((1, 1, block, hd),
                         lambda n, g, r, col, nv, off: (n, g, r, 0))
    anyspec = pl.BlockSpec(memory_space=pltpu.ANY)
    rowspec = pl.BlockSpec((1, 1, block),
                           lambda n, g, r, col, nv, off: (n, g, r))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(N, G, nrb),
        in_specs=[qspec, anyspec, anyspec, qspec, rowspec, rowspec],
        out_specs=qspec,
        scratch_shapes=[
            pltpu.VMEM((depth, block, hd), k.dtype),
            pltpu.VMEM((depth, block, hd), v.dtype),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, G, S, hd), jnp.float32),
        compiler_params=_compiler_params(config, interpret,
                                         ("parallel",) * 3),
        interpret=interpret,
    )(col_idx, nvalid, offsets, q, k, v, do, lse, delta)


# ---------------------------------------------------------------------------
# backward: dK/dV  (column-block grid over the transposed BCSR tables)
# ---------------------------------------------------------------------------

def _dkv_kernel(row_ref, nvt_ref, off_ref, q_hbm, k_ref, v_ref, do_hbm,
                lse_hbm, delta_hbm, dk_ref, dv_ref, dk_acc, dv_acc, qbuf,
                dobuf, lsebuf, dltbuf, qsem, dosem, lsesem, dltsem, *, block,
                hd, KT, G, depth, scale, causal, sliding_window):
    n = pl.program_id(0)
    c = pl.program_id(1)
    g = pl.program_id(2)
    nvt = nvt_ref[c]

    def row_copies(slot, t):
        r = row_ref[c, t]
        src = pl.ds(r * block, block)
        return (pltpu.make_async_copy(q_hbm.at[n, g, src, :], qbuf.at[slot],
                                      qsem.at[slot]),
                pltpu.make_async_copy(do_hbm.at[n, g, src, :], dobuf.at[slot],
                                      dosem.at[slot]),
                pltpu.make_async_copy(lse_hbm.at[n, g, src], lsebuf.at[slot],
                                      lsesem.at[slot]),
                pltpu.make_async_copy(delta_hbm.at[n, g, src],
                                      dltbuf.at[slot], dltsem.at[slot]))

    @pl.when(g == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    for j in range(min(depth - 1, KT)):
        for cp in row_copies(j, j):
            cp.start()

    k = k_ref[0].astype(jnp.float32)          # (B, hd) column block c
    v = v_ref[0].astype(jnp.float32)

    def step(t, carry):
        dk, dv = carry
        ahead = t + depth - 1

        @pl.when(ahead < KT)
        def _prefetch():
            for cp in row_copies(jax.lax.rem(ahead, depth), ahead):
                cp.start()

        slot = jax.lax.rem(t, depth)
        for cp in row_copies(slot, t):
            cp.wait()
        r = row_ref[c, t]
        q = qbuf[slot].astype(jnp.float32)    # (B, hd) rows of block r
        do = dobuf[slot].astype(jnp.float32)
        lse = lsebuf[slot]
        delta = dltbuf[slot]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        ok = _tile_mask(r + off_ref[0], c + off_ref[1], block, causal,
                        sliding_window)
        ok &= jnp.full((block, block), t < nvt)     # padded entries: p = 0
        p = jnp.where(ok, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        # contract the q-row axis: dv_c += p^T dO_r ; dk_c += scale ds^T Q_r
        dv = dv + jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        dk = dk + jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        return dk, dv

    dk, dv = jax.lax.fori_loop(
        0, KT, step, (jnp.zeros((block, hd), jnp.float32),
                      jnp.zeros((block, hd), jnp.float32)))
    dk_acc[...] += dk
    dv_acc[...] += dv

    @pl.when(g == G - 1)
    def _finish():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def _fused_dkv(q, k, v, do, lse, delta, row_idx, nvalid_t, *, block, causal,
               sliding_window, interpret, offsets=None, config=None):
    N, G, S, hd = q.shape
    Sk = k.shape[1]
    ncb, KT = row_idx.shape
    config = DEFAULT_CONFIG if config is None else config
    depth = _depth(config, KT)
    offsets = _zero_offsets() if offsets is None else offsets
    scale = 1.0 / np.sqrt(hd)
    kern = functools.partial(_dkv_kernel, block=block, hd=hd, KT=KT, G=G,
                             depth=depth, scale=scale, causal=causal,
                             sliding_window=sliding_window)
    anyspec = pl.BlockSpec(memory_space=pltpu.ANY)
    colspec = pl.BlockSpec((1, block, hd),
                           lambda n, c, g, row, nvt, off: (n, c, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        # g innermost so every revisit of the (n, c) output tile is
        # consecutive (the scratch accumulators persist across g)
        grid=(N, ncb, G),
        in_specs=[anyspec, colspec, colspec, anyspec, anyspec, anyspec],
        out_specs=[colspec, colspec],
        scratch_shapes=[
            pltpu.VMEM((block, hd), jnp.float32),       # dk accumulator
            pltpu.VMEM((block, hd), jnp.float32),       # dv accumulator
            pltpu.VMEM((depth, block, hd), q.dtype),    # Q row-slice ring
            pltpu.VMEM((depth, block, hd), do.dtype),   # dO row-slice ring
            pltpu.VMEM((depth, block), jnp.float32),    # lse ring
            pltpu.VMEM((depth, block), jnp.float32),    # delta ring
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
            pltpu.SemaphoreType.DMA((depth,)),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((N, Sk, hd), jnp.float32),
                   jax.ShapeDtypeStruct((N, Sk, hd), jnp.float32)],
        compiler_params=_compiler_params(
            config, interpret, ("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(row_idx, nvalid_t, offsets, q, k, v, do, lse, delta)


# ---------------------------------------------------------------------------
# custom VJP assembly
# ---------------------------------------------------------------------------

def _int_zero(x):
    """float0 cotangent for integer-dtype primal inputs (the BCSR tables)."""
    return np.zeros(x.shape, jax.dtypes.float0)


@functools.lru_cache(maxsize=None)
def _fused_op(block, causal, sliding_window, interpret, with_plan, seq_len,
              config):
    """One differentiable fused-attention op per static config (cached so the
    custom_vjp identity is stable across traces).

    with_plan=True takes precomputed transposed tables (row_idx, nvalid_t)
    as extra primal inputs — the host-built SparsityPlan path: the dK/dV
    streaming width is row_idx.shape[1] = KT* (true max column population)
    and no bcsr_transpose runs under jit. with_plan=False is the fallback
    that rebuilds the transposed tables in every backward at width KT = nrb.

    `config` is the (hashable) KernelConfig the autotuner resolved — part
    of the cache key, so differently-tuned call sites get distinct compiled
    kernels while identical configs share one.

    Every op additionally takes the `offs = [row0, col0]` block-index
    rebasing as an int32 primal (float0 cotangent); seq_len=None means "use
    q.shape[2]" — both are [0,0]/None everywhere except inside a seq shard.
    """
    fwd_ = functools.partial(_fused_forward, block=block, causal=causal,
                             sliding_window=sliding_window,
                             interpret=interpret, seq_len=seq_len,
                             config=config)

    def bwd_core(q, k, v, col_idx, nvalid, offs, o, lse, do, row_idx,
                 nvalid_t):
        """Shared backward body — both vjp variants differ only in where the
        transposed tables come from (plan residuals vs under-jit rebuild)."""
        delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), -1)
        dq = _fused_dq(q, k, v, do, lse, delta, col_idx, nvalid, block=block,
                       causal=causal, sliding_window=sliding_window,
                       interpret=interpret, offsets=offs, config=config)
        dk, dv = _fused_dkv(q, k, v, do, lse, delta, row_idx, nvalid_t,
                            block=block, causal=causal,
                            sliding_window=sliding_window, interpret=interpret,
                            offsets=offs, config=config)
        return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)

    if with_plan:
        @jax.custom_vjp
        def op(q, k, v, col_idx, nvalid, offs, row_idx, nvalid_t):
            return fwd_(q, k, v, col_idx, nvalid, offsets=offs)[0]

        def op_fwd(q, k, v, col_idx, nvalid, offs, row_idx, nvalid_t):
            o, lse = fwd_(q, k, v, col_idx, nvalid, offsets=offs)
            return o, (q, k, v, col_idx, nvalid, offs, row_idx, nvalid_t, o,
                       lse)

        def op_bwd(res, do):
            q, k, v, col_idx, nvalid, offs, row_idx, nvalid_t, o, lse = res
            dq, dk, dv = bwd_core(q, k, v, col_idx, nvalid, offs, o, lse, do,
                                  row_idx, nvalid_t)
            return (dq, dk, dv, _int_zero(col_idx), _int_zero(nvalid),
                    _int_zero(offs), _int_zero(row_idx), _int_zero(nvalid_t))

        op.defvjp(op_fwd, op_bwd)
        return op

    @jax.custom_vjp
    def op(q, k, v, col_idx, nvalid, offs):
        return fwd_(q, k, v, col_idx, nvalid, offsets=offs)[0]

    def op_fwd(q, k, v, col_idx, nvalid, offs):
        o, lse = fwd_(q, k, v, col_idx, nvalid, offsets=offs)
        return o, (q, k, v, col_idx, nvalid, offs, o, lse)

    def op_bwd(res, do):
        q, k, v, col_idx, nvalid, offs, o, lse = res
        row_idx, nvalid_t = bcsr_transpose(col_idx, nvalid,
                                           ncb=k.shape[1] // block)
        dq, dk, dv = bwd_core(q, k, v, col_idx, nvalid, offs, o, lse, do,
                              row_idx, nvalid_t)
        return dq, dk, dv, _int_zero(col_idx), _int_zero(nvalid), \
            _int_zero(offs)

    op.defvjp(op_fwd, op_bwd)
    return op


def fused_block_sparse_attention(q, k, v, col_idx, nvalid, *, block,
                                 causal=False, sliding_window=None,
                                 interpret=None, row_idx=None, nvalid_t=None,
                                 offsets=None, seq_len=None, config=None):
    """q (N, G, S, hd) — G query heads share each kv head; k, v (N, Sk, hd);
    col_idx (nrb, K) clamped, nvalid (nrb,). Returns (N, G, S, hd).

    Differentiable: jax.grad flows through Pallas dQ / dK/dV kernels (dK/dV
    sum over the G query heads of each kv head). `interpret=None` resolves
    from the platform (compiled on TPU/GPU, interpreter elsewhere).

    `config` is a dispatch.KernelConfig — normally the one the autotuner
    cached for this pattern (kernels/autotune.py); None means the default
    double-buffered schedule. Configs change only scheduling, never
    results.

    When a host-built SparsityPlan supplies `row_idx (ncb, KT*)` and
    `nvalid_t (ncb,)`, the dK/dV backward streams KT* entries per column
    block — sized to the measured pattern — and no bcsr_transpose runs
    under jit. Without them the backward falls back to the under-jit
    transpose at the always-safe width KT = ncb.

    Sequence-parallel callers (kernels/sharded.py seq mode) pass local
    tables, `offsets = [row0, col0]` (int32 (2,), the global block index of
    local Q row-block 0 and of K/V storage block 0) and the GLOBAL
    `seq_len`; Sk may then exceed S by the halo width. Meshless callers
    leave both at None (identical math to before).

    Single-shard op: under a multi-device mesh it must run inside the
    shard_map wrapper (kernels/sharded.py) — pallas_call has no GSPMD
    partitioning rule, so a bare call would be silently replicated on every
    device. That misuse fails loudly here instead.
    """
    mesh = current_mesh()
    if mesh is not None and mesh.size > 1 and not in_sharded_body():
        raise RuntimeError(
            f"fused_block_sparse_attention called under a multi-device mesh "
            f"{dict(mesh.shape)} outside the shard_map wrapper: pallas_call "
            f"has no GSPMD partitioning rule, so the kernel would run fully "
            f"replicated on every device. Route the call through "
            f"kernels.ops.spion_attention_kernel (mesh-aware) or "
            f"kernels.sharded.sharded_fused_attention, or use the jnp BCSR "
            f"path (cfg.spion.kernel='jnp').")
    if config is not None and not isinstance(config, KernelConfig):
        raise TypeError(f"config must be a dispatch.KernelConfig or None, "
                        f"got {type(config).__name__}")
    op = _fused_op(int(block), bool(causal),
                   None if sliding_window is None else int(sliding_window),
                   default_interpret(interpret), row_idx is not None,
                   None if seq_len is None else int(seq_len),
                   DEFAULT_CONFIG if config is None else config)
    offs = _zero_offsets() if offsets is None else offsets.astype(jnp.int32)
    if row_idx is not None:
        return op(q, k, v, col_idx.astype(jnp.int32), nvalid.astype(jnp.int32),
                  offs, row_idx.astype(jnp.int32), nvalid_t.astype(jnp.int32))
    return op(q, k, v, col_idx.astype(jnp.int32), nvalid.astype(jnp.int32),
              offs)
