"""Fused block-sparse flash attention — the BEYOND-PAPER kernel.

One Pallas kernel replaces the paper's SDDMM -> sparse softmax -> SpMM
pipeline: for each (batch*kv-head, q-head-in-group, row-block), the K active
KV tiles stream through VMEM with running (max, sum, acc) flash statistics.
S^r and S^s never touch HBM — this is the TPU-native realisation of the
paper's data-locality argument (DESIGN.md §2), and it removes the
O(nnz * B^2) intermediate traffic the faithful pipeline pays.

The sparse-softmax zero-correction (Alg. 6 line 15) is applied to the final
denominator, so the fused kernel is bit-compatible (up to fp assoc.) with
the 3-kernel path.

Grid: (N, G, nrb, K)  — K innermost/sequential; scratch in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(col_ref, nvalid_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, block, hd, K, seq_len, scale,
            causal, sliding_window):
    r = pl.program_id(2)
    c = pl.program_id(3)

    @pl.when(c == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(c < nvalid_ref[r])
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)      # (B, hd)
        k = k_ref[0].astype(jnp.float32)         # (B, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        col = col_ref[r, c]
        qpos = r * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
        kpos = col * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
        ok = jnp.ones((block, block), bool)
        if causal:
            ok &= qpos >= kpos
        if sliding_window is not None:
            ok &= (qpos - kpos) < sliding_window
        s = jnp.where(ok, s, NEG)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)                     # rescale factor
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, -1)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_new

    @pl.when(c == K - 1)
    def _finish():
        m = m_ref[:, 0]
        l = l_ref[:, 0]
        # Alg. 6 line 15 zero-correction: pruned positions count exp(0 - m).
        rows = r * block + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
        if causal:
            rt = (rows + 1).astype(jnp.float32)
            if sliding_window is not None:
                rt = jnp.minimum(rt, float(sliding_window))
        else:
            rt = jnp.full((block,), float(seq_len))
        # stored counts come from the same masks; recompute per active tile
        stored = jnp.zeros((block,), jnp.float32)

        def count(i, acc):
            col = col_ref[r, i]
            qpos = rows[:, None]
            kpos = col * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
            ok = jnp.full((block, block), i < nvalid_ref[r])
            if causal:
                ok &= qpos >= kpos
            if sliding_window is not None:
                ok &= (qpos - kpos) < sliding_window
            return acc + jnp.sum(ok.astype(jnp.float32), -1)

        stored = jax.lax.fori_loop(0, K, count, stored)
        denom = l + jnp.maximum(rt - stored, 0.0) * jnp.exp(-m)
        denom = jnp.where(denom == 0.0, 1.0, denom)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def fused_block_sparse_attention(q, k, v, col_idx, nvalid, *, block,
                                 causal=False, sliding_window=None,
                                 interpret=True):
    """q (N, G, S, hd) — G query heads share each kv head; k, v (N, S, hd);
    col_idx (nrb, K) clamped, nvalid (nrb,). Returns (N, G, S, hd)."""
    N, G, S, hd = q.shape
    nrb, K = col_idx.shape
    scale = 1.0 / np.sqrt(hd)
    kern = functools.partial(_kernel, block=block, hd=hd, K=K, seq_len=S,
                             scale=scale, causal=causal,
                             sliding_window=sliding_window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(N, G, nrb, K),
        in_specs=[
            pl.BlockSpec((1, 1, block, hd), lambda n, g, r, c, col, nv: (n, g, r, 0)),
            pl.BlockSpec((1, block, hd), lambda n, g, r, c, col, nv: (n, col[r, c], 0)),
            pl.BlockSpec((1, block, hd), lambda n, g, r, c, col, nv: (n, col[r, c], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block, hd),
                               lambda n, g, r, c, col, nv: (n, g, r, 0)),
        scratch_shapes=[
            pltpu.VMEM((block, 1), jnp.float32),    # running max
            pltpu.VMEM((block, 1), jnp.float32),    # running sum
            pltpu.VMEM((block, hd), jnp.float32),   # output accumulator
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, G, S, hd), q.dtype),
        interpret=interpret,
    )(col_idx, nvalid, q, k, v)
