"""jit'd public wrappers around the Pallas kernels.

`spion_attention_kernel(...)` is a drop-in for core.sparse_attention.
bcsr_attention with use_pallas semantics: handles GQA head grouping, BCSR
table clamping, and dispatches either the paper-faithful 3-kernel pipeline
or the fused flash-style kernel.

The fused path is differentiable (custom VJP with Pallas backward kernels,
see block_sparse_attn.py) — it is the path the sparse training phase runs
through. The 3-kernel pipeline stays forward-only (it exists to reproduce
the paper's Fig. 6 breakdown, not to train).

interpret=None resolves from the platform: compiled on TPU, Pallas
interpreter on CPU (CI) — the same call sites work on both.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.block_sparse_attn import fused_block_sparse_attention
from repro.kernels.dispatch import default_interpret
from repro.kernels.sddmm import sddmm
from repro.kernels.sparse_softmax import sparse_softmax
from repro.kernels.spmm import spmm


def _prep_tables(bcsr):
    col = jnp.maximum(bcsr.col_idx, 0).astype(jnp.int32)
    nvalid = bcsr.nvalid.astype(jnp.int32)
    return col, nvalid


def _split_heads(q, k, v):
    """(B,S,H,hd)x(B,S,KV,hd) -> q (B*KV, G, S, hd), k/v (B*KV, S, hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qh = q.reshape(B, S, KV, G, hd).transpose(0, 2, 3, 1, 4).reshape(B * KV, G, S, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    return qh, kh, vh, (B, S, H, hd, KV, G)


def _merge_heads(o, dims):
    B, S, H, hd, KV, G = dims
    return o.reshape(B, KV, G, S, hd).transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)


@functools.partial(jax.jit, static_argnames=("cfg", "block", "fused", "interpret"))
def _dispatch(q, k, v, col, nvalid, row_idx, nvalid_t, *, cfg, block, fused,
              interpret):
    causal = cfg.causal
    sw = cfg.sliding_window
    qh, kh, vh, dims = _split_heads(q, k, v)
    if fused:
        o = fused_block_sparse_attention(qh, kh, vh, col, nvalid, block=block,
                                         causal=causal, sliding_window=sw,
                                         interpret=interpret,
                                         row_idx=row_idx, nvalid_t=nvalid_t)
        return _merge_heads(o, dims)
    B, S, H, hd, KV, G = dims
    qf = qh.reshape(B * KV * G, S, hd)
    kf = jnp.repeat(kh, G, axis=0) if G > 1 else kh
    vf = jnp.repeat(vh, G, axis=0) if G > 1 else vh
    s = sddmm(qf, kf, col, nvalid, block=block, causal=causal,
              sliding_window=sw, interpret=interpret)
    p = sparse_softmax(s, col, nvalid, block=block, seq_len=S, causal=causal,
                       sliding_window=sw, interpret=interpret)
    o = spmm(p, vf, col, nvalid, block=block, interpret=interpret)
    return _merge_heads(o.reshape(B * KV, G, S, hd), dims)


def spion_attention_kernel(cfg, q, k, v, bcsr, *, fused=True, interpret=None,
                           row_idx=None, nvalid_t=None):
    """Pallas-kernel counterpart of core.sparse_attention.bcsr_attention.
    With fused=True the result is differentiable (sparse backward kernels).
    `row_idx`/`nvalid_t` are a SparsityPlan's precomputed transposed tables
    (width KT*); supplying them shrinks the dK/dV backward grid to the true
    pattern width and removes the per-step under-jit bcsr_transpose."""
    col, nvalid = _prep_tables(bcsr)
    return _dispatch(q, k, v, col, nvalid, row_idx, nvalid_t, cfg=cfg,
                     block=bcsr.block, fused=fused,
                     interpret=default_interpret(interpret))
