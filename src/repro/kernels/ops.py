"""jit'd public wrappers around the Pallas kernels.

`spion_attention_kernel(...)` is a drop-in for core.sparse_attention.
bcsr_attention with use_pallas semantics: handles GQA head grouping, BCSR
table clamping, and dispatches the single-pass fused flash-style kernel —
the ONLY production kernel path (DESIGN.md §15). The paper-faithful
3-kernel SDDMM -> sparse softmax -> SpMM pipeline was demoted to the
pure-jnp oracle in kernels/ref.py: it exists to check the fused kernel in
parity tests and to reproduce the Fig. 6 breakdown, not to serve traffic.

The fused path is differentiable (custom VJP with Pallas backward kernels,
see block_sparse_attn.py) — it is the path the sparse training phase runs
through.

Mesh-aware: under an active multi-device mesh (distributed.sharding.
current_mesh()) the fused path routes through the shard_map wrapper
(kernels/sharded.py) — batch shards over the data axes, KV heads over
'model' when divisible — so sparse training keeps the kernel on pods.
pallas_call has no GSPMD partitioning rule, so the only alternatives under
a mesh are the jnp BCSR path or silently replicated kernel work; the
latter fails loudly (block_sparse_attn guard).

interpret=None resolves from the platform: compiled on TPU (Mosaic) and
GPU (Triton), Pallas interpreter only where no compiled lane exists (CPU
CI) — the same call sites work everywhere.

The jits here are keyed ONLY on the kernel statics (causal, sliding_window,
block, interpret, and the autotuned KernelConfig) — never on the whole
ModelConfig, so unrelated config changes (act_shard, bench sweeps, dtype
knobs) don't retrace the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed.sharding import current_mesh
from repro.kernels.block_sparse_attn import fused_block_sparse_attention
from repro.kernels.dispatch import default_interpret


def _prep_tables(bcsr):
    col = jnp.maximum(bcsr.col_idx, 0).astype(jnp.int32)
    nvalid = bcsr.nvalid.astype(jnp.int32)
    return col, nvalid


def _split_heads(q, k, v):
    """(B,S,H,hd)x(B,S,KV,hd) -> q (B, KV, G, S, hd), k/v (B, KV, S, hd).

    B and KV stay separate leading axes so the sharded dispatch can put the
    shard boundary on meshable dims (batch over the data axes, KV heads over
    'model'); the kernels' flat B*KV leading axis is formed shard-locally
    (or in _flatten_bk for the single-shard path)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qh = q.reshape(B, S, KV, G, hd).transpose(0, 2, 3, 1, 4)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    return qh, kh, vh, (B, S, H, hd, KV, G)


def _flatten_bk(qh, kh, vh, dims):
    B, S, H, hd, KV, G = dims
    return (qh.reshape(B * KV, G, S, hd), kh.reshape(B * KV, S, hd),
            vh.reshape(B * KV, S, hd))


def _merge_heads(o, dims):
    """(B, KV, G, S, hd) -> (B, S, H, hd)."""
    B, S, H, hd, KV, G = dims
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)


@functools.partial(jax.jit, static_argnames=("causal", "sliding_window",
                                             "block", "interpret", "config"))
def _dispatch(q, k, v, col, nvalid, row_idx, nvalid_t, *, causal,
              sliding_window, block, interpret, config):
    qh, kh, vh, dims = _split_heads(q, k, v)
    B, S, H, hd, KV, G = dims
    qf, kf, vf = _flatten_bk(qh, kh, vh, dims)
    o = fused_block_sparse_attention(qf, kf, vf, col, nvalid, block=block,
                                     causal=causal,
                                     sliding_window=sliding_window,
                                     interpret=interpret,
                                     row_idx=row_idx, nvalid_t=nvalid_t,
                                     config=config)
    return _merge_heads(o.reshape(B, KV, G, S, hd), dims)


@functools.partial(jax.jit, static_argnames=("mesh", "causal",
                                             "sliding_window", "block",
                                             "interpret", "halo", "config"))
def _dispatch_sharded(q, k, v, col, nvalid, row_idx, nvalid_t, *, mesh,
                      causal, sliding_window, block, interpret, halo, config):
    from repro.kernels.sharded import sharded_fused_attention
    qh, kh, vh, dims = _split_heads(q, k, v)
    o = sharded_fused_attention(mesh, qh, kh, vh, col, nvalid, block=block,
                                causal=causal, sliding_window=sliding_window,
                                interpret=interpret, row_idx=row_idx,
                                nvalid_t=nvalid_t, halo=halo, config=config)
    return _merge_heads(o, dims)


def spion_attention_kernel(cfg, q, k, v, bcsr, *, interpret=None,
                           row_idx=None, nvalid_t=None, halo=None,
                           config=None):
    """Pallas-kernel counterpart of core.sparse_attention.bcsr_attention.
    The result is differentiable (sparse backward kernels); the single-pass
    fused kernel is the only path here — the legacy 3-kernel pipeline lives
    on solely as the kernels/ref.py oracle.
    `row_idx`/`nvalid_t` are a SparsityPlan's precomputed transposed tables
    (width KT*); supplying them shrinks the dK/dV backward streaming width
    to the true pattern width and removes the per-step under-jit
    bcsr_transpose. `halo` is the plan's static (left, right) column extent
    in block units — it unlocks 'seq'-axis sharding under a
    sequence-parallel mesh (kernels/sharded.py). `config` is the autotuned
    dispatch.KernelConfig for this pattern (kernels/autotune.py) — a
    jit-static scheduling knob that never changes results.

    Under an active multi-device mesh the fused path runs through the
    shard_map wrapper."""
    col, nvalid = _prep_tables(bcsr)
    interp = default_interpret(interpret)
    mesh = current_mesh()
    if mesh is not None and mesh.size > 1:
        return _dispatch_sharded(q, k, v, col, nvalid, row_idx, nvalid_t,
                                 mesh=mesh, causal=cfg.causal,
                                 sliding_window=cfg.sliding_window,
                                 block=bcsr.block, interpret=interp,
                                 halo=None if halo is None else
                                 (int(halo[0]), int(halo[1])),
                                 config=config)
    return _dispatch(q, k, v, col, nvalid, row_idx, nvalid_t,
                     causal=cfg.causal, sliding_window=cfg.sliding_window,
                     block=bcsr.block, interpret=interp, config=config)
