"""Pure-jnp oracles for every Pallas kernel in this package.

The oracle semantics are the paper's (Alg. 5/6): SDDMM computes only the
P-active blocks; the sparse softmax counts pruned positions as exp(0 - max)
in the denominator (Alg. 6 line 15); SpMM multiplies active blocks by V.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _block_positions(col_idx, block, nrb):
    """(qpos, kpos, valid): per (r, p, c, q) absolute positions + validity."""
    K = col_idx.shape[1]
    qpos = (jnp.arange(nrb) * block)[:, None, None, None] + jnp.arange(block)[None, :, None, None]
    colc = jnp.maximum(col_idx, 0)
    kpos = (colc * block)[:, None, :, None] + jnp.arange(block)[None, None, None, :]
    valid = (col_idx >= 0)[:, None, :, None]
    return qpos, kpos, valid


def _mask(col_idx, block, nrb, causal, sliding_window):
    qpos, kpos, valid = _block_positions(col_idx, block, nrb)
    ok = valid
    if causal:
        ok = ok & (qpos >= kpos)
    if sliding_window:
        ok = ok & (qpos - kpos < sliding_window)
    return jnp.broadcast_to(ok, (nrb, block, col_idx.shape[1], block))


def sddmm_ref(q, k, col_idx, *, block, causal=False, sliding_window=None):
    """q (N, S, hd); k (N, S, hd); col_idx (nrb, K) ->
    s_blocks (N, nrb, K, block, block) fp32 = (Q K^T / sqrt(hd)) on active
    blocks, -inf on masked positions."""
    N, S, hd = q.shape
    nrb = S // block
    K = col_idx.shape[1]
    qb = q.reshape(N, nrb, block, hd)
    kb = k.reshape(N, S // block, block, hd)
    kg = kb[:, jnp.maximum(col_idx, 0)]                      # (N, nrb, K, blk, hd)
    # s axes: n, r(row-block), p(q row), c(active block), q(k col)
    s = jnp.einsum("nrph,nrcqh->nrpcq", qb, kg).astype(jnp.float32) / np.sqrt(hd)
    ok = _mask(col_idx, block, nrb, causal, sliding_window)   # (r, p, c, q)
    s = jnp.where(ok[None], s, -jnp.inf)
    return jnp.moveaxis(s, 2, 3)  # (N, nrb, K, blk_q, blk_k)


def row_total_ref(S, block, causal, sliding_window):
    """Total positions each row would attend to densely (for the correction)."""
    if causal:
        rt = jnp.arange(S) + 1
        if sliding_window:
            rt = jnp.minimum(rt, sliding_window)
        return rt
    return jnp.full((S,), S)


def sparse_softmax_ref(s_blocks, col_idx, *, block, seq_len, causal=False,
                       sliding_window=None):
    """s_blocks (N, nrb, K, blk, blk) fp32 with -inf at masked positions ->
    probs, same shape, with the Alg. 6 zero-correction."""
    N, nrb, K, b, _ = s_blocks.shape
    flat = jnp.moveaxis(s_blocks, 2, 3).reshape(N, nrb, b, K * b)  # rows together
    mx = jnp.maximum(jnp.max(flat, -1, keepdims=True), -1e30)
    ex = jnp.where(jnp.isneginf(flat), 0.0, jnp.exp(flat - mx))
    denom = ex.sum(-1, keepdims=True)
    stored = jnp.sum(~jnp.isneginf(flat), -1, keepdims=True)
    rt = row_total_ref(seq_len, block, causal, sliding_window).reshape(nrb, b)[None, :, :, None]
    denom = denom + jnp.maximum(rt - stored, 0) * jnp.exp(-mx)
    p = ex / denom
    return jnp.moveaxis(p.reshape(N, nrb, b, K, b), 3, 2)


def spmm_ref(p_blocks, v, col_idx):
    """p_blocks (N, nrb, K, blk, blk); v (N, S, hd) -> out (N, S, hd)."""
    N, nrb, K, b, _ = p_blocks.shape
    S, hd = v.shape[1], v.shape[2]
    vb = v.reshape(N, S // b, b, hd)
    vg = vb[:, jnp.maximum(col_idx, 0)]                      # (N, nrb, K, blk, hd)
    out = jnp.einsum("nrcpq,nrcqh->nrph", p_blocks.astype(v.dtype), vg)
    return out.reshape(N, S, hd)


def fused_ref(q, k, v, col_idx, *, block, causal=False, sliding_window=None):
    """Fused oracle = sddmm -> sparse softmax -> spmm."""
    s = sddmm_ref(q, k, col_idx, block=block, causal=causal, sliding_window=sliding_window)
    p = sparse_softmax_ref(s, col_idx, block=block, seq_len=q.shape[1],
                           causal=causal, sliding_window=sliding_window)
    return spmm_ref(p, v, col_idx).astype(q.dtype)
