"""Pallas TPU block SpMM: out = S^s @ V over active BCSR blocks
(cusparseSpMM analogue, paper Alg. 5 line 7).

Grid (N, nrb, K): K is the innermost (sequential) dimension, so the output
tile for row-block r stays resident in VMEM while the K active probability
tiles stream through and accumulate on the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dispatch import default_interpret


def _kernel(col_ref, nvalid_ref, p_ref, v_ref, o_ref, *, block):
    r = pl.program_id(1)
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(c < nvalid_ref[r])
    def _acc():
        p = p_ref[0, 0, 0]                     # (B, B) fp32
        v = v_ref[0].astype(jnp.float32)       # (B, hd)
        o_ref[0] += jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        ).astype(o_ref.dtype)


def spmm(p_blocks, v, col_idx, nvalid, *, block, interpret=None):
    """p_blocks (N, nrb, K, B, B); v (N, S, hd) -> (N, S, hd) in v.dtype.
    interpret=None resolves from the platform (compiled on TPU)."""
    interpret = default_interpret(interpret)
    N, nrb, K = p_blocks.shape[:3]
    S, hd = v.shape[1], v.shape[2]
    kern = functools.partial(_kernel, block=block)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(N, nrb, K),
        in_specs=[
            pl.BlockSpec((1, 1, 1, block, block),
                         lambda n, r, c, col, nv: (n, r, c, 0, 0)),
            pl.BlockSpec((1, block, hd), lambda n, r, c, col, nv: (n, col[r, c], 0)),
        ],
        out_specs=pl.BlockSpec((1, block, hd), lambda n, r, c, col, nv: (n, r, 0)),
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, S, hd), v.dtype),
        interpret=interpret,
    )(col_idx, nvalid, p_blocks, v)
