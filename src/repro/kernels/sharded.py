"""Mesh-aware dispatch: the fused block-sparse attention kernel under
shard_map.

`pallas_call` has no GSPMD partitioning rule, so under a multi-device mesh
the fused kernel (and its custom-VJP backward kernels) would either fail or
run fully replicated on every device. This wrapper makes the kernel
mesh-native instead:

  - the kernel's leading B*KV grid axis is split back into (B, KV) so the
    shard boundary falls on meshable dims: batch shards over the data axes
    ('pod','data'), KV heads over 'model' when KV % |model| == 0, with a
    clean fallback to batch-only sharding otherwise
    (distributed.sharding.kernel_shard_axes);
  - when the mesh has a 'seq' axis and the pattern's column extent fits
    (distributed.sharding.kernel_seq_axis), Q row-blocks additionally shard
    over 'seq': the body halo-exchanges the K/V edge blocks with the two
    adjacent shards via `jax.lax.ppermute`, rebases the replicated BCSR
    tables into shard-local halo coordinates, and runs the same Pallas
    kernels over the local rows with global-coordinate offsets
    (DESIGN.md §10). Patterns too wide for the halo fall back LOUDLY to
    batch/KV sharding — correctness never depends on the pattern;
  - the BCSR + SparsityPlan tables replicate per shard (in_spec P()) — they
    index the full, unsharded sequence axis, and they are kilobytes;
  - the body flattens (B_loc, KV_loc) -> N_loc = B_loc*KV_loc shard-locally
    and calls the unchanged `fused_block_sparse_attention` custom_vjp, so
    `jax.grad` of the wrapped op differentiates straight through the
    shard_map: partial-eval splits it into a forward and a backward
    shard_map, and the custom-VJP residuals (q/k/v/tables/o/LSE) flow
    between them as shard-local values — no gather of the (N, G, S)
    log-sum-exp to the host program, no resharding of the backward. In seq
    mode the halo exchange is ordinary differentiable lax around the
    custom_vjp, so its transpose (reverse ppermute reducing the dK/dV halo
    contributions back onto the owning shard) falls out of AD.

Every grid cell is independent across N = B*KV (the tables are shared by
all batch entries and heads), so sharding the leading axis changes nothing
about the math: the sharded forward is bitwise-identical to the
single-device kernel on each shard's rows (tested).

check_rep=False for the same reason as distributed/collectives.py: the
replicated table inputs plus a custom_vjp body defeat shard_map's
replication checker.
"""
from __future__ import annotations

import warnings
from collections import OrderedDict

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh

from repro.distributed import runtime
from repro.distributed.sharding import (kernel_pspecs_from_axes,
                                        kernel_seq_axis, kernel_shard_axes)
from repro.kernels.block_sparse_attn import fused_block_sparse_attention
from repro.kernels.dispatch import default_interpret, sharded_body

# One shard_map-wrapped fused op per (mesh DESCRIPTOR, axes, static kernel
# config) — cached so repeated traces reuse the same callable (the
# custom_vjp identity under it stays stable, mirroring
# block_sparse_attn._fused_op). Keyed on a hashable mesh descriptor, NOT the
# live Mesh object: an lru_cache on the Mesh itself retained every mesh ever
# constructed (tests, serve restarts, remesh after fault recovery) forever,
# along with its device handles. Re-creating an identical mesh now hits the
# same entry (tested), and the cache is LRU-bounded as a backstop against
# descriptor churn.
_OP_CACHE: OrderedDict = OrderedDict()
_OP_CACHE_MAX = 64


def _mesh_key(mesh: Mesh):
    """Hashable identity of a mesh: axis names + shape + device ids."""
    return (tuple(mesh.axis_names),
            tuple(int(mesh.shape[a]) for a in mesh.axis_names),
            tuple(int(d.id) for d in mesh.devices.flat))


def _op_cache_size() -> int:
    return len(_OP_CACHE)


def _sharded_op(mesh: Mesh, baxes, kv_ax, seq, block, causal, sliding_window,
                interpret, with_plan, config):
    key = (_mesh_key(mesh), baxes, kv_ax, seq, block, causal, sliding_window,
           interpret, with_plan, config)
    op = _OP_CACHE.get(key)
    if op is not None:
        _OP_CACHE.move_to_end(key)
        return op
    op = _build_sharded_op(mesh, baxes, kv_ax, seq, block, causal,
                           sliding_window, interpret, with_plan, config)
    _OP_CACHE[key] = op
    while len(_OP_CACHE) > _OP_CACHE_MAX:
        _OP_CACHE.popitem(last=False)
    return op


def _build_sharded_op(mesh, baxes, kv_ax, seq, block, causal, sliding_window,
                      interpret, with_plan, config):
    """`seq` is None (sequence unsharded, PR-3 behaviour) or a static
    (n_shards, halo_left, halo_right) triple in block units."""
    seq_ax = "seq" if seq is not None else None
    qspec, kvspec, rep = kernel_pspecs_from_axes(baxes, kv_ax, seq_ax)
    n_tables = 4 if with_plan else 2

    def body(q, k, v, col_idx, nvalid, *plan):
        with sharded_body():
            B, KV, G, S, hd = q.shape  # shard-LOCAL sizes
            row_idx, nvalid_t = plan if with_plan else (None, None)
            kw = dict(block=block, causal=causal,
                      sliding_window=sliding_window, interpret=interpret,
                      config=config)
            if seq is None:
                o = fused_block_sparse_attention(
                    q.reshape(B * KV, G, S, hd), k.reshape(B * KV, S, hd),
                    v.reshape(B * KV, S, hd), col_idx, nvalid,
                    row_idx=row_idx, nvalid_t=nvalid_t, **kw)
                return o.reshape(B, KV, G, S, hd)

            n_seq, h_l, h_r = seq
            W = S // block                       # local row-blocks
            M = h_l + W + h_r                    # local K/V storage blocks
            i = jax.lax.axis_index("seq").astype(jnp.int32)
            r0 = i * W                           # global block of local row 0

            def ring(x, shift):
                """shift=+1 receives the left neighbour's tensor (ring)."""
                perm = [(j, (j + shift) % n_seq) for j in range(n_seq)]
                return jax.lax.ppermute(x, "seq", perm)

            # halo exchange: the pattern bounds which K/V blocks any local
            # row can read, so only the adjacent shards' edge blocks move
            ks, vs = [k], [v]
            if h_l:
                ks.insert(0, ring(k[:, :, S - h_l * block:, :], +1))
                vs.insert(0, ring(v[:, :, S - h_l * block:, :], +1))
            if h_r:
                ks.append(ring(k[:, :, :h_r * block, :], -1))
                vs.append(ring(v[:, :, :h_r * block, :], -1))
            kh = jnp.concatenate(ks, axis=2) if len(ks) > 1 else k
            vh = jnp.concatenate(vs, axis=2) if len(vs) > 1 else v

            # rebase the replicated forward BCSR into halo-local storage
            # coordinates: storage block s holds global column-block
            # c = r0 - h_l + s (the extent check guarantees every valid
            # entry lands in [0, M); clamped padding is skipped by nvalid)
            K_pad = col_idx.shape[1]
            col_l = jax.lax.dynamic_slice(col_idx, (r0, jnp.int32(0)),
                                          (W, K_pad))
            nv_l = jax.lax.dynamic_slice(nvalid, (r0,), (W,))
            col_l = jnp.clip(col_l - (r0 - h_l), 0, M - 1).astype(jnp.int32)
            # global-coordinate offsets for the kernels' masks and the
            # Alg. 6 zero-correction: [row0, col0]
            offs = jnp.stack([r0, r0 - h_l]).astype(jnp.int32)

            if with_plan:
                ncb, KT = row_idx.shape
                # transposed tables for the local window: storage col s ->
                # global col (mod ncb: the ring wraps at the ends; wrapped
                # columns are never referenced by local rows, so their
                # entry count is 0 and their dK/dV stays zero)
                cg = (r0 - h_l + jnp.arange(M, dtype=jnp.int32)) % ncb
                rig = row_idx[cg]                       # (M, KT) global rows
                nvtg = nvalid_t[cg]
                tpos = jnp.arange(KT, dtype=jnp.int32)[None, :]
                valid = tpos < nvtg[:, None]
                # the valid prefix lists rows ascending, so the rows owned
                # by THIS shard are a contiguous run — locate and shift it
                # left with a gather instead of a compaction sort
                lo = jnp.sum(valid & (rig < r0), axis=1).astype(jnp.int32)
                cnt = jnp.sum(valid & (rig >= r0) & (rig < r0 + W),
                              axis=1).astype(jnp.int32)
                gat = jnp.minimum(lo[:, None] + tpos, KT - 1)
                ril = jnp.take_along_axis(rig, gat, axis=1) - r0
                plan_l = dict(row_idx=jnp.clip(ril, 0, W - 1), nvalid_t=cnt)
            else:
                # plan-less: build the LOCAL transposed tables here in the
                # forward, from the rebased col table, so the custom_vjp
                # takes the with_plan path per shard. Deliberately NOT the
                # under-jit bcsr_transpose-in-the-backward fallback: its
                # scatter+argsort inside the grad-of-shard_map body
                # miscompiles under jit on CPU SPMD (wrong dK/dV for a
                # subset of column-blocks at larger N; inserting a
                # debug-print "fixes" it), so the seq path sticks to this
                # comparison/cumsum construction — maskT via equality
                # against every storage block, ranks via cumsum. O(M*W*K)
                # bools, kilobytes.
                tposk = jnp.arange(K_pad, dtype=jnp.int32)[None, None, :]
                mm = col_l[None, :, :] == \
                    jnp.arange(M, dtype=jnp.int32)[:, None, None]
                mm &= tposk < nv_l[None, :, None]
                mm = mm.any(-1)                         # (M, W) maskT
                cs = jnp.cumsum(mm, axis=1)             # actives <= row
                tpos = jnp.arange(W, dtype=jnp.int32)
                ril = jnp.sum(cs[:, :, None] <= tpos[None, None, :],
                              axis=1).astype(jnp.int32)
                plan_l = dict(row_idx=jnp.clip(ril, 0, W - 1),
                              nvalid_t=mm.sum(1).astype(jnp.int32))

            o = fused_block_sparse_attention(
                q.reshape(B * KV, G, S, hd),
                kh.reshape(B * KV, M * block, hd),
                vh.reshape(B * KV, M * block, hd), col_l, nv_l,
                offsets=offs, seq_len=n_seq * S, **plan_l, **kw)
            return o.reshape(B, KV, G, S, hd)

    return shard_map(body, mesh=mesh,
                     in_specs=(qspec, kvspec, kvspec) + (rep,) * n_tables,
                     out_specs=qspec, check_rep=False)


def sharded_fused_attention(mesh: Mesh, q, k, v, col_idx, nvalid, *, block,
                            causal=False, sliding_window=None, interpret=None,
                            row_idx=None, nvalid_t=None, halo=None,
                            config=None):
    """shard_map'd `fused_block_sparse_attention` over `mesh`.

    q (B, KV, G, S, hd); k, v (B, KV, S, hd) — batch and KV heads as
    separate leading axes (ops._split_heads layout); tables as in
    `fused_block_sparse_attention`; interpret=None resolves from the
    platform (kernels/dispatch.py). Returns (B, KV, G, S, hd).

    `config` is the autotuned dispatch.KernelConfig (or None for defaults);
    it is part of the op-cache key, so differently-tuned patterns build
    separate shard_map ops while identical configs share one.

    `halo` is the pattern's (left, right) column extent in block units
    (SparsityPlan stats["halo"]). When the mesh has a 'seq' axis and the
    halo fits the shard width (kernel_seq_axis), the sequence axis shards
    too: Q rows split over 'seq', K/V edge blocks halo-exchange via
    ppermute, tables rebase into shard-local coordinates. Too-wide
    patterns (or halo=None) fall back to batch/KV sharding with a loud
    warning — never a silent full-sequence exchange.

    Differentiable end-to-end: jax.grad flows through the shard_map into the
    custom-VJP Pallas backward kernels, each shard running its own dQ/dK/dV
    grids over its local rows (seq mode reduces the dK/dV halo
    contributions back with the reverse permute, via AD of the exchange).
    Raises when no mesh axis can shard the kernel — running it replicated
    on every device is never the intended dispatch; use the jnp path there.
    """
    B, KV, S = q.shape[0], q.shape[1], q.shape[3]
    baxes, kv_ax = kernel_shard_axes(mesh, B, KV)
    seq_ax, seq_reason = kernel_seq_axis(mesh, S // block, halo)
    seq = None
    if seq_ax is not None:
        n_seq = mesh.shape["seq"]
        seq = (int(n_seq), int(halo[0]), int(halo[1]))
    elif mesh.shape.get("seq", 1) > 1:
        if baxes is None and kv_ax is None:
            raise RuntimeError(
                f"sharded_fused_attention: mesh {dict(mesh.shape)} has a "
                f"'seq' axis but the kernel cannot seq-shard ({seq_reason}) "
                f"and no batch/KV axis divides either (batch={B}, "
                f"kv_heads={KV}). Narrow the pattern (or supply the "
                f"SparsityPlan halo), fix the divisibility, or use "
                f"kernel='jnp' (the GSPMD path).")
        if runtime.is_coordinator():
            # every process takes this SPMD branch together; on a
            # multi-host fleet one copy of the warning beats N identical
            # ones (the RuntimeErrors above stay per-process: a crash
            # should explain itself in every worker's log)
            warnings.warn(
                f"sharded_fused_attention: mesh {dict(mesh.shape)} has a "
                f"'seq' axis but the kernel falls back to batch/KV sharding "
                f"— {seq_reason}. The kernel work is replicated |seq|="
                f"{mesh.shape['seq']}x; narrow the pattern or drop the "
                f"'seq' axis.", stacklevel=2)
    if baxes is None and kv_ax is None and seq is None:
        raise RuntimeError(
            f"sharded_fused_attention: no mesh axis shards the kernel on "
            f"mesh {dict(mesh.shape)} — batch={B} is indivisible by the data "
            f"axes and kv_heads={KV} by 'model'. The shard_map would run the "
            f"full kernel replicated on every device; use kernel='jnp' (the "
            f"GSPMD path) or fix the batch/head divisibility.")
    op = _sharded_op(mesh, baxes, kv_ax, seq, int(block), bool(causal),
                     None if sliding_window is None else int(sliding_window),
                     default_interpret(interpret), row_idx is not None,
                     config)
    args = (q, k, v, col_idx, nvalid)
    if row_idx is not None:
        args += (row_idx, nvalid_t)
    return op(*args)
