"""Mesh-aware dispatch: the fused block-sparse attention kernel under
shard_map.

`pallas_call` has no GSPMD partitioning rule, so under a multi-device mesh
the fused kernel (and its custom-VJP backward kernels) would either fail or
run fully replicated on every device. This wrapper makes the kernel
mesh-native instead:

  - the kernel's leading B*KV grid axis is split back into (B, KV) so the
    shard boundary falls on meshable dims: batch shards over the data axes
    ('pod','data'), KV heads over 'model' when KV % |model| == 0, with a
    clean fallback to batch-only sharding otherwise
    (distributed.sharding.kernel_shard_axes);
  - the BCSR + SparsityPlan tables replicate per shard (in_spec P()) — they
    index the full, unsharded sequence axis, and they are kilobytes;
  - the body flattens (B_loc, KV_loc) -> N_loc = B_loc*KV_loc shard-locally
    and calls the unchanged `fused_block_sparse_attention` custom_vjp, so
    `jax.grad` of the wrapped op differentiates straight through the
    shard_map: partial-eval splits it into a forward and a backward
    shard_map, and the custom-VJP residuals (q/k/v/tables/o/LSE) flow
    between them as shard-local values — no gather of the (N, G, S)
    log-sum-exp to the host program, no resharding of the backward.

Every grid cell is independent across N = B*KV (the tables are shared by
all batch entries and heads), so sharding the leading axis changes nothing
about the math: the sharded forward is bitwise-identical to the
single-device kernel on each shard's rows (tested).

check_rep=False for the same reason as distributed/collectives.py: the
replicated table inputs plus a custom_vjp body defeat shard_map's
replication checker.
"""
from __future__ import annotations

import functools

from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh

from repro.distributed.sharding import (kernel_pspecs_from_axes,
                                        kernel_shard_axes)
from repro.kernels.block_sparse_attn import fused_block_sparse_attention
from repro.kernels.dispatch import default_interpret, sharded_body


@functools.lru_cache(maxsize=None)
def _sharded_op(mesh: Mesh, baxes, kv_ax, block, causal, sliding_window,
                interpret, with_plan):
    """One shard_map-wrapped fused op per (mesh, axes, static kernel config)
    — cached so repeated traces reuse the same callable (and the custom_vjp
    identity under it stays stable, mirroring block_sparse_attn._fused_op)."""
    qspec, kvspec, rep = kernel_pspecs_from_axes(baxes, kv_ax)
    n_tables = 4 if with_plan else 2

    def body(q, k, v, col_idx, nvalid, *plan):
        with sharded_body():
            B, KV, G, S, hd = q.shape  # shard-LOCAL sizes
            row_idx, nvalid_t = plan if with_plan else (None, None)
            o = fused_block_sparse_attention(
                q.reshape(B * KV, G, S, hd), k.reshape(B * KV, S, hd),
                v.reshape(B * KV, S, hd), col_idx, nvalid, block=block,
                causal=causal, sliding_window=sliding_window,
                interpret=interpret, row_idx=row_idx, nvalid_t=nvalid_t)
            return o.reshape(B, KV, G, S, hd)

    return shard_map(body, mesh=mesh,
                     in_specs=(qspec, kvspec, kvspec) + (rep,) * n_tables,
                     out_specs=qspec, check_rep=False)


def sharded_fused_attention(mesh: Mesh, q, k, v, col_idx, nvalid, *, block,
                            causal=False, sliding_window=None, interpret=None,
                            row_idx=None, nvalid_t=None):
    """shard_map'd `fused_block_sparse_attention` over `mesh`.

    q (B, KV, G, S, hd); k, v (B, KV, S, hd) — batch and KV heads as
    separate leading axes (ops._split_heads layout); tables as in
    `fused_block_sparse_attention`; interpret=None resolves from the
    platform (kernels/dispatch.py). Returns (B, KV, G, S, hd).

    Differentiable end-to-end: jax.grad flows through the shard_map into the
    custom-VJP Pallas backward kernels, each shard running its own dQ/dK/dV
    grids over its local rows. Raises when no mesh axis can shard the
    kernel (batch indivisible by the data axes AND KV indivisible by
    'model') — running the kernel replicated on every device is never the
    intended dispatch; use the jnp path there instead.
    """
    B, KV = q.shape[0], q.shape[1]
    baxes, kv_ax = kernel_shard_axes(mesh, B, KV)
    if baxes is None and kv_ax is None:
        raise RuntimeError(
            f"sharded_fused_attention: no mesh axis shards the kernel on "
            f"mesh {dict(mesh.shape)} — batch={B} is indivisible by the data "
            f"axes and kv_heads={KV} by 'model'. The shard_map would run the "
            f"full kernel replicated on every device; use kernel='jnp' (the "
            f"GSPMD path) or fix the batch/head divisibility.")
    op = _sharded_op(mesh, baxes, kv_ax, int(block), bool(causal),
                     None if sliding_window is None else int(sliding_window),
                     default_interpret(interpret), row_idx is not None)
    args = (q, k, v, col_idx, nvalid)
    if row_idx is not None:
        args += (row_idx, nvalid_t)
    return op(*args)
