"""Pallas TPU SDDMM: S^r = (P>0) ⊙ (Q K^T / sqrt(hd)) on active BCSR blocks.

TPU adaptation of cusparseSDDMM (paper Alg. 5 line 5): instead of element-CSR,
each grid step computes one (block x block) MXU tile Q_r @ K_c^T where
c = col_idx[r, k]. The column-block table rides in SMEM via scalar prefetch;
BlockSpec index maps gather K tiles straight from HBM -> VMEM.

Grid: (N, nrb, K)   N = batch*heads (kv-broadcast handled in ops.py)
Blocks: q (1, B, hd) VMEM; k (1, B, hd) VMEM gathered by col table;
        out (1, 1, 1, B, B) VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dispatch import default_interpret


def _kernel(col_ref, nvalid_ref, q_ref, k_ref, o_ref, *, block, scale,
            causal, sliding_window):
    r = pl.program_id(1)
    c = pl.program_id(2)
    q = q_ref[0].astype(jnp.float32)          # (B, hd)
    k = k_ref[0].astype(jnp.float32)          # (B, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    col = col_ref[r, c]
    qpos = r * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    kpos = col * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    ok = jnp.full((block, block), c < nvalid_ref[r])
    if causal:
        ok &= qpos >= kpos
    if sliding_window is not None:
        ok &= (qpos - kpos) < sliding_window
    o_ref[0, 0, 0] = jnp.where(ok, s, -jnp.inf)


def sddmm(q, k, col_idx, nvalid, *, block, causal=False, sliding_window=None,
          interpret=None):
    """q, k: (N, S, hd); col_idx (nrb, K) int32 (clamped >= 0);
    nvalid (nrb,) int32. Returns s_blocks (N, nrb, K, block, block) fp32.
    interpret=None resolves from the platform (compiled on TPU)."""
    interpret = default_interpret(interpret)
    N, S, hd = q.shape
    nrb, K = col_idx.shape
    scale = 1.0 / np.sqrt(hd)

    kern = functools.partial(_kernel, block=block, scale=scale,
                             causal=causal, sliding_window=sliding_window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(N, nrb, K),
        in_specs=[
            pl.BlockSpec((1, block, hd), lambda n, r, c, col, nv: (n, r, 0)),
            pl.BlockSpec((1, block, hd), lambda n, r, c, col, nv: (n, col[r, c], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, block, block),
                               lambda n, r, c, col, nv: (n, r, c, 0, 0)),
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, nrb, K, block, block), jnp.float32),
        interpret=interpret,
    )(col_idx, nvalid, q, k)
