"""Platform-aware kernel dispatch knobs.

`interpret=None` everywhere in this package means "resolve from the
platform": Pallas kernels compile through Mosaic on TPU and fall back to the
pure-Python interpreter elsewhere (CPU CI, dev laptops), so the same call
sites run unchanged on both. Pass an explicit bool to override.

Also home of the shard_map-body marker: `pallas_call` has no GSPMD
partitioning rule, so under a multi-device mesh the fused kernel is only
correct inside the shard_map wrapper (kernels/sharded.py). The wrapper
flags its body trace with `sharded_body()`; `fused_block_sparse_attention`
checks `in_sharded_body()` and fails loudly instead of letting GSPMD run
the kernel fully replicated on every device. (Lives here, not in
sharded.py, to keep block_sparse_attn <-> sharded import-acyclic.)
"""
from __future__ import annotations

import contextlib
import contextvars
import functools

import jax

_IN_SHARDED_BODY: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_in_sharded_kernel_body", default=False)


@contextlib.contextmanager
def sharded_body():
    """Mark the current (trace-time) scope as inside the shard_map wrapper."""
    tok = _IN_SHARDED_BODY.set(True)
    try:
        yield
    finally:
        _IN_SHARDED_BODY.reset(tok)


def in_sharded_body() -> bool:
    return _IN_SHARDED_BODY.get()


@functools.lru_cache(maxsize=1)
def _platform_interpret() -> bool:
    return jax.default_backend() != "tpu"


def default_interpret(interpret=None) -> bool:
    """Resolve a tri-state interpret flag (None -> platform default)."""
    if interpret is None:
        return _platform_interpret()
    return bool(interpret)
