"""Platform-aware kernel dispatch knobs.

`interpret=None` everywhere in this package means "resolve from the
platform": Pallas kernels compile through Mosaic on TPU and fall back to the
pure-Python interpreter elsewhere (CPU CI, dev laptops), so the same call
sites run unchanged on both. Pass an explicit bool to override.
"""
from __future__ import annotations

import functools

import jax


@functools.lru_cache(maxsize=1)
def _platform_interpret() -> bool:
    return jax.default_backend() != "tpu"


def default_interpret(interpret=None) -> bool:
    """Resolve a tri-state interpret flag (None -> platform default)."""
    if interpret is None:
        return _platform_interpret()
    return bool(interpret)
