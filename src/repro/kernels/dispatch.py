"""Platform-aware kernel dispatch knobs.

`interpret=None` everywhere in this package means "resolve from the
platform": Pallas kernels lower through a real compiler on COMPILED
backends — Mosaic on TPU, Triton on GPU — and fall back to the pure-Python
interpreter only where no compiled lane exists (CPU CI, dev laptops), so
the same call sites run unchanged everywhere. Pass an explicit bool to
override. GPU deliberately counts as compiled: dropping a CUDA host to the
interpreter would silently throw away the wall-clock the kernels exist for;
if a kernel cannot lower on a backend the failure must be loud, not a
silent 100x slowdown.

Also home of `KernelConfig` — the hashable per-kernel tuning knob bundle
(DMA pipeline depth, Mosaic dimension semantics, Triton num_warps /
num_stages) swept by kernels/autotune.py and threaded as a jit-static
through ops/block_sparse_attn/sharded. It lives here, not in autotune.py,
because block_sparse_attn needs the type and autotune imports
block_sparse_attn (import-acyclic).

Also home of the shard_map-body marker: `pallas_call` has no GSPMD
partitioning rule, so under a multi-device mesh the fused kernel is only
correct inside the shard_map wrapper (kernels/sharded.py). The wrapper
flags its body trace with `sharded_body()`; `fused_block_sparse_attention`
checks `in_sharded_body()` and fails loudly instead of letting GSPMD run
the kernel fully replicated on every device. (Lives here, not in
sharded.py, to keep block_sparse_attn <-> sharded import-acyclic.)
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import functools

import jax

_IN_SHARDED_BODY: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_in_sharded_kernel_body", default=False)

# backends with a real Pallas compiler lane (Mosaic / Triton). Everything
# else (cpu, METAL, ...) resolves interpret=None to the interpreter.
COMPILED_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One point in the fused-kernel tuning space (kernels/autotune.py).

    Hashable and immutable: it rides jit static_argnames, the _fused_op
    lru_cache key, and SparseAttentionExec pytree aux, so two execs with
    different tuned configs trace separately and identical configs share
    the compiled kernel.

      depth               K/V (bwd: Q/dO/lse/delta) DMA pipeline depth in
                          block_sparse_attn — 1 is a synchronous fetch,
                          2 the classic double buffer, 3+ deeper rings.
      dimension_semantics Mosaic grid annotation for the fwd/dQ grids
                          (None -> all-parallel). The dK/dV grid pins its
                          own (g must stay sequential for the scratch
                          accumulators).
      num_warps/num_stages Triton lowering knobs (GPU); None -> compiler
                          defaults. Ignored by Mosaic and the interpreter.

    Changing a config can only ever change SPEED: every field controls
    scheduling (prefetch distance, grid parallelism, warp mapping), never
    the operation order inside a block, so tuned and default outputs are
    bitwise identical (tests/test_autotune.py holds this line).
    """
    depth: int = 2
    dimension_semantics: tuple | None = None
    num_warps: int | None = None
    num_stages: int | None = None

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        if self.dimension_semantics is not None:
            d["dimension_semantics"] = list(self.dimension_semantics)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "KernelConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - fields
        if unknown:
            raise ValueError(f"unknown KernelConfig fields: {sorted(unknown)}")
        kw = dict(d)
        if kw.get("dimension_semantics") is not None:
            kw["dimension_semantics"] = tuple(kw["dimension_semantics"])
        cfg = cls(**kw)
        if not isinstance(cfg.depth, int) or cfg.depth < 1:
            raise ValueError(f"KernelConfig.depth must be an int >= 1, "
                             f"got {cfg.depth!r}")
        return cfg


DEFAULT_CONFIG = KernelConfig()


@contextlib.contextmanager
def sharded_body():
    """Mark the current (trace-time) scope as inside the shard_map wrapper."""
    tok = _IN_SHARDED_BODY.set(True)
    try:
        yield
    finally:
        _IN_SHARDED_BODY.reset(tok)


def in_sharded_body() -> bool:
    return _IN_SHARDED_BODY.get()


@functools.lru_cache(maxsize=1)
def _platform_interpret() -> bool:
    # interpret only where there is NO compiled lane — GPU (Triton) is a
    # compiled backend exactly like TPU (Mosaic), not an interpreter host.
    return jax.default_backend() not in COMPILED_BACKENDS


def compiled_backend() -> str | None:
    """The compiled-lane name for this host ("tpu" / "gpu") or None.

    cuda/rocm normalise to "gpu" — both lower through Triton."""
    backend = jax.default_backend()
    if backend == "tpu":
        return "tpu"
    if backend in ("gpu", "cuda", "rocm"):
        return "gpu"
    return None


def is_compiled_backend() -> bool:
    return compiled_backend() is not None


def default_interpret(interpret=None) -> bool:
    """Resolve a tri-state interpret flag (None -> platform default)."""
    if interpret is None:
        return _platform_interpret()
    return bool(interpret)
