"""Decoder/encoder transformer LM with scan-over-layers.

Families served here: dense (qwen/mistral/command-r), moe (mixtral/arctic),
vlm (internvl2 backbone + stub patch tokens), encoder (spion-lra).
SPION hooks: `spion` (per-layer BCSR tables) switches self-attention to the
block-sparse path; `capture` streams pooled conv scores for pattern
generation during the dense phase.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.attention_exec import SparseAttentionExec
from repro.core.kv_pool import PagedKVCache, scatter_token, write_target
from repro.distributed.sharding import constrain
from repro.models import attention as A
from repro.models import layers as Lyr
from repro.models.moe import moe_apply, moe_init


MAX_POS = 65_536  # learned-position table bound (largest non-RoPE shape)


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": Lyr.norm_init(cfg, dtype=jnp.float32),
        "attn": A.attn_init(ks[0], cfg, dtype=dtype),
        "mlp_norm": Lyr.norm_init(cfg, dtype=jnp.float32),
    }
    if cfg.moe is not None:
        p["moe"] = moe_init(ks[1], cfg, dtype=dtype)
    else:
        p["mlp"] = Lyr.mlp_init(ks[1], cfg, dtype=dtype)
    return p


def init(key, cfg):
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    params: Dict[str, Any] = {
        "tok_embed": Lyr.embed_init(ks[1], cfg.vocab_size, cfg.d_model, dtype),
        "layers": jax.vmap(lambda k: layer_init(k, cfg, dtype))(layer_keys),
        "final_norm": Lyr.norm_init(cfg, dtype=jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = Lyr.embed_init(ks[2], cfg.vocab_size, cfg.d_model, dtype)
    if not cfg.rope_theta:
        params["pos_embed"] = {"w": (jax.random.normal(ks[3], (MAX_POS, cfg.d_model)) * 0.02).astype(dtype)}
    return params


def _self_attention(cfg, p, h, positions, ex, sp, capture, collect_kv=False):
    """One layer's attention; returns (out, captured_or_zeros, kv_or_None).

    `ex` is the phase's SparseAttentionExec (None in the dense phase); `sp`
    this layer's slice of its scanned tables. collect_kv=True additionally
    returns the RoPE'd (k, v) — the fused serving prefill inserts them
    straight into decode-cache slots."""
    x = Lyr.norm(cfg, p["attn_norm"], h)
    q, k, v = A.qkv(cfg, p["attn"], x, positions)
    cap = jnp.zeros((), jnp.float32)
    if capture is not None:
        cap = A.capture_pooled_scores(cfg, q, k, positions, positions,
                                      capture["filt"], capture["block"])  # (pooled, frob)
    if sp is not None:
        ctx = ex.attend(cfg, q, k, v, sp)
    else:
        pos1d = positions
        ctx = A.dense_attention(cfg, q, k, v, pos1d, pos1d)
    kv = (k, v) if collect_kv else None
    return A.attn_out(cfg, p["attn"], ctx), cap, kv


def _block(cfg, p, h, positions, ex, sp, capture, collect_kv=False):
    attn_y, cap, kv = _self_attention(cfg, p, h, positions, ex, sp, capture,
                                      collect_kv)
    h = h + attn_y
    x = Lyr.norm(cfg, p["mlp_norm"], h)
    if cfg.moe is not None:
        y, aux = moe_apply(cfg, p["moe"], x)
        aux = {k_: v_.astype(jnp.float32) for k_, v_ in aux.items()}
    else:
        y = Lyr.mlp(cfg, p["mlp"], x)
        aux = {"lb_loss": jnp.zeros((), jnp.float32), "z_loss": jnp.zeros((), jnp.float32)}
    if collect_kv:
        return h + y, cap, aux, kv
    return h + y, cap, aux


def _embed_inputs(cfg, params, batch, dtype):
    tokens = batch["tokens"]
    h = Lyr.embed(params["tok_embed"], tokens, dtype)
    if cfg.num_patch_tokens and "patch_embeds" in batch:
        h = jnp.concatenate([batch["patch_embeds"].astype(dtype), h], axis=1)
    S = h.shape[1]
    positions = jnp.arange(S)
    if not cfg.rope_theta and "pos_embed" in params:
        h = h + params["pos_embed"]["w"][:S].astype(dtype)
    return h, positions


def forward(params, cfg, batch, *, spion=None, capture=None,
            collect_kv=False):
    """batch: {'tokens': (B,S') [, 'patch_embeds': (B,P,d)]} -> logits (B,S,V).

    spion: None | SparseAttentionExec | legacy tables dict (coerced — see
           core/attention_exec.py; the exec owns the resolved kernel, the
           plan tables and the static block/halo metadata).
    capture: None | {'filt': (F,), 'block': int} -> also returns
             (Ly, S/B, S/B) pooled conv scores for pattern generation.
    collect_kv: also return the per-layer RoPE'd K/V, stacked (L,B,S,KV,hd)
             — the fused serving prefill writes them into cache slots.
             Return becomes (logits, aux, (ks, vs)).
    """
    dtype = _dtype(cfg)
    ex = SparseAttentionExec.coerce(spion)
    h, positions = _embed_inputs(cfg, params, batch, dtype)
    h = constrain(h, "batch", "model" if cfg.act_shard == "seq" else None,
                  "model" if cfg.act_shard == "d" else None)

    def body(h, xs):
        lp, sp = xs

        def run(h, lp, sp):
            return _block(cfg, lp, h, positions, ex, sp, capture, collect_kv)
        if cfg.remat:
            run = jax.checkpoint(run, prevent_cse=False)
        if collect_kv:
            h, cap, aux, kv = run(h, lp, sp)
            return h, (cap, aux, kv)
        h, cap, aux = run(h, lp, sp)
        return h, (cap, aux)

    sp_stacked = None if ex is None else ex.scan_tables()
    h, ys = jax.lax.scan(body, h, (params["layers"], sp_stacked),
                         unroll=cfg.scan_unroll)
    caps, auxs = ys[0], ys[1]

    h = Lyr.norm(cfg, params["final_norm"], h)
    head = params["lm_head" if "lm_head" in params else "tok_embed"]
    logits = Lyr.unembed(head, h)
    logits = constrain(logits, "batch", None, "model")
    aux = {k: jnp.mean(v) for k, v in auxs.items()}
    if capture is not None:
        aux["captured"] = caps
    if collect_kv:
        return logits, aux, ys[2]
    return logits, aux


# ---------------------------------------------------------------------------
# decode (KV cache)
# ---------------------------------------------------------------------------

def init_cache(cfg, batch_size, max_len, dtype=None):
    dtype = dtype or jnp.dtype(cfg.cache_dtype or cfg.dtype)
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch_size, max_len, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(params, cfg, cache, tokens, pos, *, spion=None):
    """tokens (B,1) at absolute position `pos` — an int32 scalar (every row
    at the same position, the legacy synchronous form) or a (B,) vector of
    per-row positions (the continuous-batching engine: each cache slot
    decodes at its own offset). Returns (logits (B,V), new cache).

    spion: None | SparseAttentionExec (phase "decode") | legacy tables
    payload — when present, attention gathers only the cache blocks the
    query position's pattern row lists (sparse decode, DESIGN.md §11)
    instead of reading the whole cache; composes with the sliding-window
    ring buffer.

    The cache is either the contiguous per-slot dict {"k","v"} from
    `init_cache` or a core.kv_pool.PagedKVCache — a shared page pool plus
    per-request page tables. The paged form carries the pool through the
    layer scan as CARRY and scatter-updates only each row's active page
    (kv_pool.scatter_token), instead of rewriting every slot's whole strip
    through the scan ys — the PR 5 decode floor."""
    if isinstance(cache, PagedKVCache):
        return _paged_decode_step(params, cfg, cache, tokens, pos,
                                  spion=spion)
    dtype = _dtype(cfg)
    ex = SparseAttentionExec.coerce(spion, phase="decode")
    B = tokens.shape[0]
    posb = A.decode_positions(pos, B)
    h = Lyr.embed(params["tok_embed"], tokens, dtype)
    if not cfg.rope_theta and "pos_embed" in params:
        h = h + jnp.take(params["pos_embed"]["w"], posb, axis=0).astype(dtype)[:, None]
    positions = posb[:, None]
    h = constrain(h, "batch", None, None)
    dec = None if ex is None else ex.scan_tables()

    def body(h, xs):
        if ex is None:
            lp, kc, vc = xs
            dl = None
        else:
            lp, kc, vc, dl = xs
        x = Lyr.norm(cfg, lp["attn_norm"], h)
        q, k_new, v_new = A.qkv(cfg, lp["attn"], x, positions)
        cache_len = kc.shape[1]
        ring = bool(cfg.sliding_window)
        slot = A.cache_slot(cfg, posb, cache_len) if ring else posb
        kc, vc = A.update_cache(kc, vc, k_new, v_new, slot)
        if dl is not None:
            ctx = ex.decode(cfg, q, kc, vc, posb, dl, ring=ring)
        else:
            kpos = A.ring_kpos(posb, cache_len) if ring else None
            ctx = A.decode_attention(cfg, q, kc, vc, posb, kpos=kpos)
        h = h + A.attn_out(cfg, lp["attn"], ctx)
        x = Lyr.norm(cfg, lp["mlp_norm"], h)
        if cfg.moe is not None:
            y, _ = moe_apply(cfg, lp["moe"], x)
        else:
            y = Lyr.mlp(cfg, lp["mlp"], x)
        return h + y, (kc, vc)

    xs = (params["layers"], cache["k"], cache["v"])
    if ex is not None:
        xs = xs + (dec,)
    h, (ks, vs) = jax.lax.scan(body, h, xs, unroll=cfg.scan_unroll)
    h = Lyr.norm(cfg, params["final_norm"], h)
    head = params["lm_head" if "lm_head" in params else "tok_embed"]
    logits = Lyr.unembed(head, h)[:, 0]
    return constrain(logits, "batch", "model"), {"k": ks, "v": vs}


def _paged_decode_step(params, cfg, cache, tokens, pos, *, spion=None):
    """`decode_step` over a PagedKVCache. The pool arrays ride the scan
    CARRY (donated in-place under jit), each layer scatter-writes the new
    token into the row's active physical page, and attention gathers
    through the page table — sparse (exec.decode_paged) or dense
    (attention.paged_decode_attention). The page table itself is constant
    through the step and is passed back out unchanged (aliasing the donated
    input)."""
    dtype = _dtype(cfg)
    ex = SparseAttentionExec.coerce(spion, phase="decode")
    B = tokens.shape[0]
    posb = A.decode_positions(pos, B)
    h = Lyr.embed(params["tok_embed"], tokens, dtype)
    if not cfg.rope_theta and "pos_embed" in params:
        h = h + jnp.take(params["pos_embed"]["w"], posb, axis=0).astype(dtype)[:, None]
    positions = posb[:, None]
    h = constrain(h, "batch", None, None)
    dec = None if ex is None else ex.scan_tables()
    pt = cache.pt
    ring = bool(cfg.sliding_window)
    phys_w, off_w = write_target(pt, posb, cache.page, ring=ring)

    def body(carry, xs):
        h, kp, vp = carry
        if ex is None:
            lp, li = xs
            dl = None
        else:
            lp, li, dl = xs
        x = Lyr.norm(cfg, lp["attn_norm"], h)
        q, k_new, v_new = A.qkv(cfg, lp["attn"], x, positions)
        kp, vp = scatter_token(kp, vp, li, k_new, v_new, phys_w, off_w)
        if dl is not None:
            ctx = ex.decode_paged(cfg, q, kp, vp, li, posb, pt, dl, ring=ring)
        else:
            ctx = A.paged_decode_attention(cfg, q, kp, vp, li, posb, pt,
                                           page=cache.page)
        h = h + A.attn_out(cfg, lp["attn"], ctx)
        x = Lyr.norm(cfg, lp["mlp_norm"], h)
        if cfg.moe is not None:
            y, _ = moe_apply(cfg, lp["moe"], x)
        else:
            y = Lyr.mlp(cfg, lp["mlp"], x)
        return (h + y, kp, vp), None

    xs = (params["layers"], jnp.arange(cfg.num_layers))
    if ex is not None:
        xs = xs + (dec,)
    (h, kp, vp), _ = jax.lax.scan(body, (h, cache.kp, cache.vp), xs,
                                  unroll=cfg.scan_unroll)
    h = Lyr.norm(cfg, params["final_norm"], h)
    head = params["lm_head" if "lm_head" in params else "tok_embed"]
    logits = Lyr.unembed(head, h)[:, 0]
    return constrain(logits, "batch", "model"), \
        PagedKVCache(kp, vp, pt, page=cache.page)


def prefill_step(params, cfg, batch, *, spion=None):
    """Fused serving prefill: one full-sequence forward over the prompt that
    also returns every layer's RoPE'd K/V for direct insertion into decode
    cache slots — (logits (B,S,V), ks (L,B,S,KV,hd), vs (L,B,S,KV,hd)).

    Causality makes padding free: logits and K/V at positions < P are
    unaffected by whatever sits after the prompt, so the serving engine can
    pad prompts to a bucketed length (bounding retraces) and insert only
    the real positions."""
    logits, _aux, (ks, vs) = forward(params, cfg, batch, spion=spion,
                                     collect_kv=True)
    return logits, ks, vs
