"""Decoder/encoder transformer LM with scan-over-layers.

Families served here: dense (qwen/mistral/command-r), moe (mixtral/arctic),
vlm (internvl2 backbone + stub patch tokens), encoder (spion-lra).
SPION hooks: `spion` (per-layer BCSR tables) switches self-attention to the
block-sparse path; `capture` streams pooled conv scores for pattern
generation during the dense phase.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.sparse_attention import PLAN_TABLE_KEYS
from repro.distributed.sharding import constrain
from repro.models import attention as A
from repro.models import layers as Lyr
from repro.models.moe import moe_apply, moe_init


MAX_POS = 65_536  # learned-position table bound (largest non-RoPE shape)


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": Lyr.norm_init(cfg, dtype=jnp.float32),
        "attn": A.attn_init(ks[0], cfg, dtype=dtype),
        "mlp_norm": Lyr.norm_init(cfg, dtype=jnp.float32),
    }
    if cfg.moe is not None:
        p["moe"] = moe_init(ks[1], cfg, dtype=dtype)
    else:
        p["mlp"] = Lyr.mlp_init(ks[1], cfg, dtype=dtype)
    return p


def init(key, cfg):
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    params: Dict[str, Any] = {
        "tok_embed": Lyr.embed_init(ks[1], cfg.vocab_size, cfg.d_model, dtype),
        "layers": jax.vmap(lambda k: layer_init(k, cfg, dtype))(layer_keys),
        "final_norm": Lyr.norm_init(cfg, dtype=jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = Lyr.embed_init(ks[2], cfg.vocab_size, cfg.d_model, dtype)
    if not cfg.rope_theta:
        params["pos_embed"] = {"w": (jax.random.normal(ks[3], (MAX_POS, cfg.d_model)) * 0.02).astype(dtype)}
    return params


def _self_attention(cfg, p, h, positions, spion_layer, capture):
    """One layer's attention; returns (out, captured_or_zeros)."""
    x = Lyr.norm(cfg, p["attn_norm"], h)
    q, k, v = A.qkv(cfg, p["attn"], x, positions)
    cap = jnp.zeros((), jnp.float32)
    if capture is not None:
        cap = A.capture_pooled_scores(cfg, q, k, positions, positions,
                                      capture["filt"], capture["block"])  # (pooled, frob)
    if spion_layer is not None:
        ctx = A.spion_sparse_attention(cfg, q, k, v, spion_layer)
    else:
        pos1d = positions
        ctx = A.dense_attention(cfg, q, k, v, pos1d, pos1d)
    return A.attn_out(cfg, p["attn"], ctx), cap


def _block(cfg, p, h, positions, spion_layer, capture):
    attn_y, cap = _self_attention(cfg, p, h, positions, spion_layer, capture)
    h = h + attn_y
    x = Lyr.norm(cfg, p["mlp_norm"], h)
    if cfg.moe is not None:
        y, aux = moe_apply(cfg, p["moe"], x)
        aux = {k_: v_.astype(jnp.float32) for k_, v_ in aux.items()}
    else:
        y = Lyr.mlp(cfg, p["mlp"], x)
        aux = {"lb_loss": jnp.zeros((), jnp.float32), "z_loss": jnp.zeros((), jnp.float32)}
    return h + y, cap, aux


def _embed_inputs(cfg, params, batch, dtype):
    tokens = batch["tokens"]
    h = Lyr.embed(params["tok_embed"], tokens, dtype)
    if cfg.num_patch_tokens and "patch_embeds" in batch:
        h = jnp.concatenate([batch["patch_embeds"].astype(dtype), h], axis=1)
    S = h.shape[1]
    positions = jnp.arange(S)
    if not cfg.rope_theta and "pos_embed" in params:
        h = h + params["pos_embed"]["w"][:S].astype(dtype)
    return h, positions


def forward(params, cfg, batch, *, spion=None, capture=None):
    """batch: {'tokens': (B,S') [, 'patch_embeds': (B,P,d)]} -> logits (B,S,V).

    spion: None | {'col_idx': (Ly,nrb,K), 'nvalid': (Ly,nrb), 'block': int}
           optionally + SparsityPlan transposed tables
           {'row_idx': (Ly,ncb,KT*), 'nvalid_t': (Ly,ncb)} (sparse backward
           grid sized to the true pattern width)
    capture: None | {'filt': (F,), 'block': int} -> also returns
             (Ly, S/B, S/B) pooled conv scores for pattern generation.
    """
    dtype = _dtype(cfg)
    h, positions = _embed_inputs(cfg, params, batch, dtype)
    h = constrain(h, "batch", "model" if cfg.act_shard == "seq" else None,
                  "model" if cfg.act_shard == "d" else None)

    def body(h, xs):
        lp, sp = xs

        def run(h, lp, sp):
            return _block(cfg, lp, h, positions,
                          None if sp is None else
                          {**sp, "block": spion["block"],
                           "halo": spion.get("halo")},
                          capture)
        if cfg.remat:
            run = jax.checkpoint(run, prevent_cse=False)
        h, cap, aux = run(h, lp, sp)
        return h, (cap, aux)

    if spion is not None:
        sp_stacked = {k: spion[k] for k in PLAN_TABLE_KEYS if k in spion}
    else:
        sp_stacked = None
    h, (caps, auxs) = jax.lax.scan(body, h, (params["layers"], sp_stacked),
                                   unroll=cfg.scan_unroll)

    h = Lyr.norm(cfg, params["final_norm"], h)
    head = params["lm_head" if "lm_head" in params else "tok_embed"]
    logits = Lyr.unembed(head, h)
    logits = constrain(logits, "batch", None, "model")
    aux = {k: jnp.mean(v) for k, v in auxs.items()}
    if capture is not None:
        aux["captured"] = caps
    return logits, aux


# ---------------------------------------------------------------------------
# decode (KV cache)
# ---------------------------------------------------------------------------

def init_cache(cfg, batch_size, max_len, dtype=None):
    dtype = dtype or jnp.dtype(cfg.cache_dtype or cfg.dtype)
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch_size, max_len, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(params, cfg, cache, tokens, pos):
    """tokens (B,1) at absolute position `pos` (int32 scalar).
    Returns (logits (B,V), new cache)."""
    dtype = _dtype(cfg)
    h = Lyr.embed(params["tok_embed"], tokens, dtype)
    if not cfg.rope_theta and "pos_embed" in params:
        h = h + jax.lax.dynamic_slice_in_dim(params["pos_embed"]["w"], pos, 1, 0).astype(dtype)[None]
    positions = pos[None] if jnp.ndim(pos) == 0 else pos
    h = constrain(h, "batch", None, None)

    def body(h, xs):
        lp, kc, vc = xs
        x = Lyr.norm(cfg, lp["attn_norm"], h)
        q, k_new, v_new = A.qkv(cfg, lp["attn"], x, positions.astype(jnp.int32))
        cache_len = kc.shape[1]
        slot = A.cache_slot(cfg, pos, cache_len) if cfg.sliding_window else pos
        kpos = A.ring_kpos(pos, cache_len) if cfg.sliding_window else None
        kc, vc = A.update_cache(kc, vc, k_new, v_new, slot)
        ctx = A.decode_attention(cfg, q, kc, vc, pos, kpos=kpos)
        h = h + A.attn_out(cfg, lp["attn"], ctx)
        x = Lyr.norm(cfg, lp["mlp_norm"], h)
        if cfg.moe is not None:
            y, _ = moe_apply(cfg, lp["moe"], x)
        else:
            y = Lyr.mlp(cfg, lp["mlp"], x)
        return h + y, (kc, vc)

    h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], cache["k"], cache["v"]),
                               unroll=cfg.scan_unroll)
    h = Lyr.norm(cfg, params["final_norm"], h)
    head = params["lm_head" if "lm_head" in params else "tok_embed"]
    logits = Lyr.unembed(head, h)[:, 0]
    return constrain(logits, "batch", "model"), {"k": ks, "v": vs}
