"""Mamba2 (SSD) layer — chunked state-space dual form.

Per head h with scalar decay a_t = Δ_t·A_h (<= 0):
    h_t = exp(a_t) h_{t-1} + Δ_t (B_t ⊗ x_t)     state (N, P)
    y_t = C_t @ h_t + D_h x_t
All exponents are cumsum differences <= 0 (safe). Short causal depthwise
conv (width 4) on the xBC stream, gated output, as in the reference model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as Lyr
from repro.models.layers import _he

CONV_W = 4
NGROUPS = 1


def mamba_init(key, cfg, dtype):
    d = cfg.d_model
    ssm = cfg.ssm
    inner = ssm.expand * d
    H = inner // ssm.head_dim
    N = ssm.state_size
    conv_dim = inner + 2 * NGROUPS * N
    ks = jax.random.split(key, 5)
    return {
        "in_proj": _he(ks[0], (d, 2 * inner + 2 * NGROUPS * N + H), d, dtype),
        "conv_w": (jax.random.normal(ks[1], (CONV_W, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log)
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),   # softplus(-2) ~ .13
        "out_norm": Lyr.rmsnorm_init(inner, jnp.float32),
        "out_proj": _he(ks[2], (inner, d), inner, dtype),
    }


def _causal_conv(x, w, b, state=None):
    """depthwise causal conv: x (B,S,C), w (W,C). state (B,W-1,C) for decode."""
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):].astype(jnp.float32) if state is not None else None
    return jax.nn.silu(y + b), new_state


def ssd_chunked(x, dt, A, B, C, D, chunk, unroll=1):
    """x (b,S,H,P); dt (b,S,H) (>0); A (H,) (<0); B,C (b,S,G,N); D (H,).
    Returns y (b,S,H,P)."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    Ch = min(chunk, S)
    n = S // Ch
    xr = x.reshape(b, n, Ch, H, P).astype(jnp.float32)
    dtr = dt.reshape(b, n, Ch, H).astype(jnp.float32)
    Br = B.reshape(b, n, Ch, NGROUPS, N).astype(jnp.float32)
    Cr = C.reshape(b, n, Ch, NGROUPS, N).astype(jnp.float32)

    # chunk-PARALLEL form (see rwkv.wkv6_chunked): heavy math batched over
    # the chunk axis; only the small state combine is sequential.
    a = dtr * A                                     # (b,n,Ch,H) <= 0
    cum = jnp.cumsum(a, axis=2)                     # inclusive
    last = cum[:, :, -1]                            # (b,n,H)

    dec_k = jnp.exp(last[:, :, None] - cum) * dtr   # (b,n,Ch,H)
    delta = jnp.einsum("bnsgq,bnshp,bnsh->bnhqp", Br, xr, dec_k)
    decay = jnp.exp(last)                           # (b,n,H)

    def comb(S_in, xcomb):
        d, dl = xcomb
        return S_in * d[..., None, None] + dl, S_in

    S0 = jnp.zeros((b, H, N, P), jnp.float32)
    _, S_in = jax.lax.scan(comb, S0, (jnp.swapaxes(decay, 0, 1),
                                      jnp.swapaxes(delta, 0, 1)))
    S_in = jnp.swapaxes(S_in, 0, 1)                 # (b,n,H,N,P)

    # inter-chunk: y_t += exp(cum_t) C_t @ S_in
    y_inter = jnp.einsum("bntgq,bnhqp->bnthp", Cr, S_in) * jnp.exp(cum)[..., None]

    # intra-chunk: M_ts = C_t.B_s exp(cum_t - cum_s) dt_s, s <= t
    Dm = cum[:, :, :, None] - cum[:, :, None, :]    # (b,n,Ch,Ch,H)
    mask = (jnp.arange(Ch)[:, None] >= jnp.arange(Ch)[None, :])[None, None, :, :, None]
    expD = jnp.where(mask, jnp.exp(jnp.minimum(Dm, 0.0)), 0.0)
    CB = jnp.einsum("bntgq,bnsgq->bnts", Cr, Br)
    M = CB[..., None] * expD * dtr[:, :, None, :, :]
    y_intra = jnp.einsum("bntsh,bnshp->bnthp", M, xr)

    y = (y_inter + y_intra).astype(x.dtype).reshape(b, S, H, P)
    return y + x * D[None, None, :, None].astype(x.dtype)


def mamba_apply(cfg, p, x, state=None):
    """x (B,S,d). state None | dict(conv (B,W-1,convdim), ssm (B,H,N,P))."""
    Bsz, S, d = x.shape
    ssm = cfg.ssm
    inner = ssm.expand * d
    H = inner // ssm.head_dim
    P = ssm.head_dim
    N = ssm.state_size

    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    # split: z (inner), xBC (inner + 2GN), dt (H)
    z = zxbcdt[..., :inner]
    xBC = zxbcdt[..., inner:inner + inner + 2 * NGROUPS * N]
    dt_raw = zxbcdt[..., -H:]
    xBC = constrain(xBC, "batch", None, "model")

    conv_state = None if state is None else state["conv"]
    xBC, new_conv = _causal_conv(xBC, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype), conv_state)
    xs = xBC[..., :inner].reshape(Bsz, S, H, P)
    Bmat = xBC[..., inner:inner + NGROUPS * N].reshape(Bsz, S, NGROUPS, N)
    Cmat = xBC[..., inner + NGROUPS * N:].reshape(Bsz, S, NGROUPS, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    if state is None:
        y = ssd_chunked(xs, dt, A, Bmat, Cmat, p["D"], ssm.chunk, unroll=cfg.scan_unroll)
        new_ssm = None
    else:
        S_in = state["ssm"]  # (B,H,N,P)
        a = (dt[:, 0] * A)  # (B,H)
        x1 = xs[:, 0].astype(jnp.float32)
        B1 = Bmat[:, 0, 0].astype(jnp.float32)  # (B,N) with G=1
        C1 = Cmat[:, 0, 0].astype(jnp.float32)
        S_new = S_in * jnp.exp(a)[..., None, None] + \
            jnp.einsum("bn,bhp,bh->bhnp", B1, x1, dt[:, 0])
        y = jnp.einsum("bn,bhnp->bhp", C1, S_new) + x1 * p["D"][None, :, None]
        y = y[:, None].astype(x.dtype)
        new_ssm = S_new

    y = y.reshape(Bsz, S, inner)
    y = Lyr.rmsnorm(p["out_norm"], y.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    new_state = None if state is None else {"conv": new_conv, "ssm": new_ssm}
    return out, new_state


def init_state(cfg, batch_size):
    ssm = cfg.ssm
    inner = ssm.expand * cfg.d_model
    H = inner // ssm.head_dim
    conv_dim = inner + 2 * NGROUPS * ssm.state_size
    return {
        "conv": jnp.zeros((batch_size, CONV_W - 1, conv_dim), jnp.float32),
        "ssm": jnp.zeros((batch_size, H, ssm.state_size, ssm.head_dim), jnp.float32),
    }
