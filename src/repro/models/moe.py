"""GShard/Switch-style top-k MoE with einsum dispatch — GSPMD-friendly.

Tokens are grouped; the dispatch/combine one-hots are (G, S_g, E, C) so GSPMD
shards groups over the data axes and experts over the model axis (arctic:
128e/16 = 8 experts per device; mixtral: 8e -> TP-within-expert via the d_ff
rules in distributed/sharding.py).
Aux losses: load-balance (Switch) + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import _he

GROUP = 1024            # tokens per dispatch group
CAPACITY_FACTOR = 1.25


def moe_init(key, cfg, dtype=jnp.float32):
    d, ff = cfg.d_model, cfg.d_ff
    E = cfg.moe.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": {"w": _he(ks[0], (d, E), d, jnp.float32)},
        "experts": {
            "w_in": _he(ks[1], (E, d, ff), d, dtype),
            "w_gate": _he(ks[2], (E, d, ff), d, dtype),
            "w_out": _he(ks[3], (E, ff, d), ff, dtype),
        },
    }
    if cfg.moe.dense_residual_ff:
        from repro.models.layers import mlp_init
        p["dense_residual"] = mlp_init(ks[4], cfg, ff=cfg.moe.dense_residual_ff, dtype=dtype)
    return p


def moe_apply(cfg, p, x):
    """x (B,S,d) -> (y (B,S,d), aux dict)."""
    B, S, d = x.shape
    E = cfg.moe.num_experts
    k = cfg.moe.top_k
    T = B * S
    g = max(1, T // GROUP)
    sg = T // g
    xt = x.reshape(g, sg, d)

    logits = (xt.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)  # (g, sg, E)
    probs = jax.nn.softmax(logits, axis=-1)

    # capacity per expert; floor keeps tiny (decode) batches dropless
    C = max(int(sg * k * CAPACITY_FACTOR / E), min(sg * k, 8))

    # top-k routing with per-expert capacity via cumulative position
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                 # (g, sg, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)  # renormalise

    dispatch = jnp.zeros((g, sg, E, C), x.dtype)
    combine = jnp.zeros((g, sg, E, C), jnp.float32)
    for slot in range(k):
        onehot = jax.nn.one_hot(gate_idx[..., slot], E, dtype=jnp.float32)  # (g,sg,E)
        pos = jnp.cumsum(onehot, axis=1) - onehot  # position within expert
        for prev in range(slot):
            # slot-major ordering: all of slot `prev`'s assignments precede
            # slot `slot`'s, so offset by the TOTAL per-expert count (GShard)
            prev_oh = jax.nn.one_hot(gate_idx[..., prev], E, dtype=jnp.float32)
            pos = pos + jnp.sum(prev_oh, axis=1, keepdims=True)
        keep = (pos < C) * onehot
        posc = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)   # (g,sg,E->?,C)
        d_oh = keep[..., None] * posc                                         # (g,sg,E,C)
        dispatch = dispatch + d_oh.astype(x.dtype)
        combine = combine + d_oh * gate_vals[..., slot][..., None, None]

    dispatch = constrain(dispatch, "batch", None, "model", None)
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xt)                # (g,E,C,d)
    xe = constrain(xe, "batch", "model", None, None)
    w = p["experts"]
    h = jnp.einsum("gecd,edf->gecf", xe, w["w_in"].astype(x.dtype))
    h = h * jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, w["w_gate"].astype(x.dtype)))
    ye = jnp.einsum("gecf,efd->gecd", h, w["w_out"].astype(x.dtype))
    ye = constrain(ye, "batch", "model", None, None)
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)

    # aux losses
    me = jnp.mean(probs, axis=1)                                    # (g,E)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], E), axis=1)
    lb_loss = E * jnp.mean(jnp.sum(me * ce, axis=-1))
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    y = y.reshape(B, S, d)
    if "dense_residual" in p:
        from repro.models.layers import mlp
        y = y + mlp(cfg, p["dense_residual"], x)
    return y, {"lb_loss": lb_loss, "z_loss": z_loss}
