from repro.models.registry import build, input_specs  # noqa: F401
