"""Pure-functional building blocks. Params are plain dict pytrees; every
layer is (init, apply) with no hidden state. Compute dtype follows the input;
norm statistics and softmax run in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain


def _he(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape) / np.sqrt(max(fan_in, 1))).astype(dtype)


# -- norms -------------------------------------------------------------------

def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * p["scale"].astype(x.dtype)


def layernorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)


def norm_init(cfg, d=None, dtype=jnp.float32):
    d = d or cfg.d_model
    return layernorm_init(d, dtype) if cfg.act in ("gelu", "relu") and cfg.family in ("encoder", "audio") \
        else rmsnorm_init(d, dtype)


def norm(cfg, p, x):
    if "bias" in p:
        return layernorm(p, x, cfg.norm_eps)
    return rmsnorm(p, x, cfg.norm_eps)


# -- linear / embedding ------------------------------------------------------

def linear_init(key, din, dout, bias=False, dtype=jnp.float32):
    p = {"w": _he(key, (din, dout), din, dtype)}
    if bias:
        p["b"] = jnp.zeros((dout,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def embed_init(key, vocab, d, dtype=jnp.float32):
    return {"w": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(p, tokens, dtype):
    return jnp.take(p["w"], tokens, axis=0).astype(dtype)


def unembed(p, x):
    """Tied or standalone LM head: x (.., d) @ w.T (vocab, d)."""
    return x @ p["w"].astype(x.dtype).T


# -- positions ---------------------------------------------------------------

def sinusoidal_positions(length, d):
    pos = np.arange(length)[:, None]
    dim = np.arange(d // 2)[None, :]
    inv = 1.0 / (10_000 ** (2 * dim / d))
    ang = pos * inv
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), jnp.float32)


def rope(x, positions, theta):
    """x: (..., seq, heads, head_dim). positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# -- mlp ---------------------------------------------------------------------

def mlp_init(key, cfg, d=None, ff=None, dtype=jnp.float32):
    d = d or cfg.d_model
    ff = ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "silu":  # gated (swiglu)
        return {
            "w_in": _he(ks[0], (d, ff), d, dtype),
            "w_gate": _he(ks[1], (d, ff), d, dtype),
            "w_out": _he(ks[2], (ff, d), ff, dtype),
        }
    return {
        "w_in": _he(ks[0], (d, ff), d, dtype),
        "w_out": _he(ks[2], (ff, d), ff, dtype),
    }


def mlp(cfg, p, x):
    h = x @ p["w_in"].astype(x.dtype)
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * h
    elif cfg.act == "relu":
        h = jax.nn.relu(h)
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", None, "model")
    y = h @ p["w_out"].astype(x.dtype)
    mode = getattr(cfg, "act_shard", None)
    if mode == "d":
        y = constrain(y, "batch", None, "model")
    elif mode == "seq":
        y = constrain(y, "batch", "model", None)
    if getattr(cfg, "ar_bf16", False):
        y = jax.lax.optimization_barrier(y)
    return y


def dropout(key, x, rate, train):
    if not train or rate <= 0.0:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0).astype(x.dtype)
