"""Whisper-family encoder-decoder backbone. The mel/conv frontend is a STUB:
batch["frames"] carries precomputed frame embeddings (B, S_enc, d) per the
assignment spec. Sinusoidal positions on the encoder, learned on the decoder,
LayerNorm + GELU — matching the whisper architecture family.
SPION applies to encoder self-attention and decoder self-attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.attention_exec import SparseAttentionExec
from repro.core.kv_pool import PagedKVCache, scatter_token, write_target
from repro.distributed.sharding import constrain
from repro.models import attention as A
from repro.models import layers as Lyr


MAX_POS = 65_536  # learned-position table bound (largest non-RoPE shape)


def _enc_cfg(cfg):
    return cfg.replace(causal=False)


def enc_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": Lyr.layernorm_init(cfg.d_model, jnp.float32),
        "attn": A.attn_init(ks[0], cfg, dtype=dtype),
        "mlp_norm": Lyr.layernorm_init(cfg.d_model, jnp.float32),
        "mlp": Lyr.mlp_init(ks[1], cfg, dtype=dtype),
    }


def dec_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    p = enc_layer_init(key, cfg, dtype)
    p["cross_norm"] = Lyr.layernorm_init(cfg.d_model, jnp.float32)
    p["cross"] = A.attn_init(ks[2], cfg, dtype=dtype)
    return p


def init(key, cfg):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    ekeys = jax.random.split(ks[0], cfg.encoder_layers)
    dkeys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "enc_layers": jax.vmap(lambda k: enc_layer_init(k, cfg, dtype))(ekeys),
        "enc_norm": Lyr.layernorm_init(cfg.d_model, jnp.float32),
        "tok_embed": Lyr.embed_init(ks[2], cfg.vocab_size, cfg.d_model, dtype),
        "pos_embed": {"w": (jax.random.normal(ks[3], (MAX_POS, cfg.d_model)) * 0.02).astype(dtype)},
        "dec_layers": jax.vmap(lambda k: dec_layer_init(k, cfg, dtype))(dkeys),
        "final_norm": Lyr.layernorm_init(cfg.d_model, jnp.float32),
    }


def _enc_block(cfg, lp, h, positions, spion_layer, capture):
    ecfg = _enc_cfg(cfg)
    x = Lyr.layernorm(lp["attn_norm"], h.astype(jnp.float32)).astype(h.dtype)
    q, k, v = A.qkv(ecfg, lp["attn"], x, positions)
    cap = jnp.zeros((), jnp.float32)
    if capture is not None:
        cap = A.capture_pooled_scores(ecfg, q, k, positions, positions,
                                      capture["filt"], capture["block"])
    if spion_layer is not None:
        ctx = A.spion_sparse_attention(ecfg, q, k, v, spion_layer)
    else:
        ctx = A.dense_attention(ecfg, q, k, v, positions, positions)
    h = h + A.attn_out(ecfg, lp["attn"], ctx)
    x = Lyr.layernorm(lp["mlp_norm"], h.astype(jnp.float32)).astype(h.dtype)
    return h + Lyr.mlp(cfg, lp["mlp"], x), cap


def encode(params, cfg, frames):
    dtype = jnp.dtype(cfg.dtype)
    h = frames.astype(dtype)
    S = h.shape[1]
    h = h + Lyr.sinusoidal_positions(S, cfg.d_model).astype(dtype)[None]
    positions = jnp.arange(S)

    def body(h, lp):
        def run(h, lp):
            y, _ = _enc_block(cfg, lp, h, positions, None, None)
            return y
        if cfg.remat:
            run = jax.checkpoint(run, prevent_cse=False)
        return run(h, lp), jnp.zeros(())

    h, _ = jax.lax.scan(body, h, params["enc_layers"], unroll=cfg.scan_unroll)
    return Lyr.layernorm(params["enc_norm"], h.astype(jnp.float32)).astype(dtype)


def forward(params, cfg, batch, *, spion=None, capture=None):
    """batch: frames (B,S_enc,d), tokens (B,S_dec). `spion` is a
    SparseAttentionExec or the legacy tables payload (decoder self-attention
    only; cross-attention stays dense)."""
    dtype = jnp.dtype(cfg.dtype)
    ex = SparseAttentionExec.coerce(spion)
    enc = encode(params, cfg, batch["frames"])
    enc = constrain(enc, "batch", None, None)
    tokens = batch["tokens"]
    S = tokens.shape[1]
    h = Lyr.embed(params["tok_embed"], tokens, dtype)
    h = h + params["pos_embed"]["w"][:S].astype(dtype)[None]
    positions = jnp.arange(S)
    enc_positions = jnp.arange(enc.shape[1])

    def body(h, xs):
        lp, sp = xs

        def run(h, lp, sp):
            # causal self-attention (SPION-able)
            x = Lyr.layernorm(lp["attn_norm"], h.astype(jnp.float32)).astype(h.dtype)
            q, k, v = A.qkv(cfg, lp["attn"], x, positions)
            cap = jnp.zeros((), jnp.float32)
            if capture is not None:
                cap = A.capture_pooled_scores(cfg, q, k, positions, positions,
                                              capture["filt"], capture["block"])
            if sp is not None:
                ctx = ex.attend(cfg, q, k, v, sp)
            else:
                ctx = A.dense_attention(cfg, q, k, v, positions, positions)
            h = h + A.attn_out(cfg, lp["attn"], ctx)
            # cross-attention (dense; non-causal)
            ccfg = _enc_cfg(cfg)
            x = Lyr.layernorm(lp["cross_norm"], h.astype(jnp.float32)).astype(h.dtype)
            qc, _, _ = A.qkv(ccfg, lp["cross"], x, positions)
            _, kc, vc = A.qkv(ccfg, lp["cross"], enc, enc_positions)
            ctx = A.dense_attention(ccfg, qc, kc, vc, positions, enc_positions)
            h = h + A.attn_out(ccfg, lp["cross"], ctx)
            x = Lyr.layernorm(lp["mlp_norm"], h.astype(jnp.float32)).astype(h.dtype)
            return h + Lyr.mlp(cfg, lp["mlp"], x), cap
        if cfg.remat:
            run = jax.checkpoint(run, prevent_cse=False)
        h, cap = run(h, lp, sp)
        return h, cap

    sp_stacked = None if ex is None else ex.scan_tables()
    h, caps = jax.lax.scan(body, h, (params["dec_layers"], sp_stacked),
                           unroll=cfg.scan_unroll)
    h = Lyr.layernorm(params["final_norm"], h.astype(jnp.float32)).astype(dtype)
    logits = Lyr.unembed(params["tok_embed"], h)
    aux = {"captured": caps} if capture is not None else {}
    return constrain(logits, "batch", None, "model"), aux


# -- decode ------------------------------------------------------------------

def init_cache(cfg, batch_size, max_len, enc_len=None, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    enc_len = enc_len or max_len
    L = cfg.num_layers
    return {
        "k": jnp.zeros((L, batch_size, max_len, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((L, batch_size, max_len, cfg.num_kv_heads, hd), dtype),
        # precomputed cross-attention K/V from the encoder output
        "ck": jnp.zeros((L, batch_size, enc_len, cfg.num_kv_heads, hd), dtype),
        "cv": jnp.zeros((L, batch_size, enc_len, cfg.num_kv_heads, hd), dtype),
    }


def precompute_cross(params, cfg, frames):
    """Run encoder once; fill ck/cv for every decoder layer."""
    enc = encode(params, cfg, frames)
    enc_positions = jnp.arange(enc.shape[1])
    ccfg = _enc_cfg(cfg)

    def per_layer(lp):
        _, kc, vc = A.qkv(ccfg, lp["cross"], enc, enc_positions)
        return kc, vc

    ck, cv = jax.vmap(per_layer)(params["dec_layers"])
    return ck, cv


def decode_step(params, cfg, cache, tokens, pos, *, spion=None):
    """pos scalar or (B,) per-row positions; `spion` (exec or payload)
    switches decoder self-attention to the pattern-bounded sparse decode —
    cross-attention reads the whole precomputed encoder K/V either way.

    Paged form: cache {"kv": core.kv_pool.PagedKVCache, "ck", "cv"} — the
    decoder self-attention K/V live in the shared page pool (scan CARRY,
    in-place page scatter) while the precomputed cross K/V stay contiguous
    (they are written once at admission and never grow)."""
    if isinstance(cache, dict) and isinstance(cache.get("kv"), PagedKVCache):
        return _paged_decode_step(params, cfg, cache, tokens, pos,
                                  spion=spion)
    dtype = jnp.dtype(cfg.dtype)
    ex = SparseAttentionExec.coerce(spion, phase="decode")
    B = tokens.shape[0]
    posb = A.decode_positions(pos, B)
    h = Lyr.embed(params["tok_embed"], tokens, dtype)
    h = h + jnp.take(params["pos_embed"]["w"], posb, axis=0).astype(dtype)[:, None]
    positions = posb[:, None]
    ccfg = _enc_cfg(cfg)
    enc_len = cache["ck"].shape[3 - 1]
    dec = None if ex is None else ex.scan_tables()

    def body(h, xs):
        if ex is None:
            lp, kc, vc, ck, cv = xs
            dl = None
        else:
            lp, kc, vc, ck, cv, dl = xs
        x = Lyr.layernorm(lp["attn_norm"], h.astype(jnp.float32)).astype(h.dtype)
        q, k_new, v_new = A.qkv(cfg, lp["attn"], x, positions)
        kc, vc = A.update_cache(kc, vc, k_new, v_new, posb)
        if dl is not None:
            ctx = ex.decode(cfg, q, kc, vc, posb, dl)
        else:
            ctx = A.decode_attention(cfg, q, kc, vc, posb)
        h = h + A.attn_out(cfg, lp["attn"], ctx)
        x = Lyr.layernorm(lp["cross_norm"], h.astype(jnp.float32)).astype(h.dtype)
        qc, _, _ = A.qkv(ccfg, lp["cross"], x, positions)
        ctx = A.decode_attention(ccfg.replace(causal=False), qc, ck, cv, jnp.asarray(enc_len - 1))
        h = h + A.attn_out(ccfg, lp["cross"], ctx)
        x = Lyr.layernorm(lp["mlp_norm"], h.astype(jnp.float32)).astype(h.dtype)
        h = h + Lyr.mlp(cfg, lp["mlp"], x)
        return h, (kc, vc)

    xs = (params["dec_layers"], cache["k"], cache["v"], cache["ck"], cache["cv"])
    if ex is not None:
        xs = xs + (dec,)
    h, (ks, vs) = jax.lax.scan(body, h, xs, unroll=cfg.scan_unroll)
    h = Lyr.layernorm(params["final_norm"], h.astype(jnp.float32)).astype(dtype)
    logits = Lyr.unembed(params["tok_embed"], h)[:, 0]
    return logits, {**cache, "k": ks, "v": vs}


def _paged_decode_step(params, cfg, cache, tokens, pos, *, spion=None):
    """Paged decoder self-attention: the pool rides the scan carry with an
    in-place page scatter per layer; cross K/V stay scanned xs (read-only)."""
    dtype = jnp.dtype(cfg.dtype)
    ex = SparseAttentionExec.coerce(spion, phase="decode")
    B = tokens.shape[0]
    posb = A.decode_positions(pos, B)
    h = Lyr.embed(params["tok_embed"], tokens, dtype)
    h = h + jnp.take(params["pos_embed"]["w"], posb, axis=0).astype(dtype)[:, None]
    positions = posb[:, None]
    ccfg = _enc_cfg(cfg)
    enc_len = cache["ck"].shape[2]
    dec = None if ex is None else ex.scan_tables()
    pkv = cache["kv"]
    pt = pkv.pt
    phys_w, off_w = write_target(pt, posb, pkv.page, ring=False)

    def body(carry, xs):
        h, kp, vp = carry
        if ex is None:
            lp, ck, cv, li = xs
            dl = None
        else:
            lp, ck, cv, li, dl = xs
        x = Lyr.layernorm(lp["attn_norm"], h.astype(jnp.float32)).astype(h.dtype)
        q, k_new, v_new = A.qkv(cfg, lp["attn"], x, positions)
        kp, vp = scatter_token(kp, vp, li, k_new, v_new, phys_w, off_w)
        if dl is not None:
            ctx = ex.decode_paged(cfg, q, kp, vp, li, posb, pt, dl)
        else:
            ctx = A.paged_decode_attention(cfg, q, kp, vp, li, posb, pt,
                                           page=pkv.page)
        h = h + A.attn_out(cfg, lp["attn"], ctx)
        x = Lyr.layernorm(lp["cross_norm"], h.astype(jnp.float32)).astype(h.dtype)
        qc, _, _ = A.qkv(ccfg, lp["cross"], x, positions)
        ctx = A.decode_attention(ccfg.replace(causal=False), qc, ck, cv, jnp.asarray(enc_len - 1))
        h = h + A.attn_out(ccfg, lp["cross"], ctx)
        x = Lyr.layernorm(lp["mlp_norm"], h.astype(jnp.float32)).astype(h.dtype)
        h = h + Lyr.mlp(cfg, lp["mlp"], x)
        return (h, kp, vp), None

    xs = (params["dec_layers"], cache["ck"], cache["cv"],
          jnp.arange(cfg.num_layers))
    if ex is not None:
        xs = xs + (dec,)
    (h, kp, vp), _ = jax.lax.scan(body, (h, pkv.kp, pkv.vp), xs,
                                  unroll=cfg.scan_unroll)
    h = Lyr.layernorm(params["final_norm"], h.astype(jnp.float32)).astype(dtype)
    logits = Lyr.unembed(params["tok_embed"], h)[:, 0]
    return logits, {**cache, "kv": PagedKVCache(kp, vp, pt, page=pkv.page)}
