"""Attention: dense GQA (train/prefill), KV-cache decode, the SPION
pattern-capture path that streams pooled diagonal-conv scores without ever
materialising the L x L attention matrix (DESIGN.md §2), and the sparse-phase
dispatch (`spion_sparse_attention`) that routes the BCSR tables either to the
pure-jnp gather path or the fused differentiable Pallas kernel — mesh-aware:
under a multi-device mesh the fused path runs through the shard_map wrapper
(DESIGN.md §9).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse_attention import BCSR, bcsr_attention
from repro.distributed.sharding import constrain, current_mesh
from repro.models.layers import _he, linear, rope


class AttnParams(NamedTuple):
    pass  # attention params are plain dicts; NamedTuple kept out intentionally


def attn_init(key, cfg, dtype=jnp.float32, d=None):
    d = d or cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _he(ks[0], (d, cfg.num_heads * hd), d, dtype),
        "wk": _he(ks[1], (d, cfg.num_kv_heads * hd), d, dtype),
        "wv": _he(ks[2], (d, cfg.num_kv_heads * hd), d, dtype),
        "wo": _he(ks[3], (cfg.num_heads * hd, d), cfg.num_heads * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    return p


def qkv(cfg, p, x, positions):
    """x (B,S,d) -> q (B,S,H,hd), k/v (B,S,KV,hd), RoPE applied."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    # constrain on the merged head dim; GSPMD propagates through the reshape
    # (a 4-D heads constraint forces involuntary remat when H % |model| != 0)
    q = constrain(q, "batch", None, "model")
    k = constrain(k, "batch", None, "model")
    v = constrain(v, "batch", None, "model")
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.rope_theta:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask_bias(cfg, q_pos, k_pos, dtype):
    """additive mask (..., Sq, Sk): 0 allowed / -inf blocked."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if cfg.causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if cfg.sliding_window:
        ok &= q_pos[:, None] - k_pos[None, :] < cfg.sliding_window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def _attn_chunk(cfg, qc, k, v, qp, k_pos):
    """One query chunk: qc (B,c,KV,G,hd) vs full k/v -> (B,c,KV,G,hd)."""
    hd = qc.shape[-1]
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qc, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd) + _mask_bias(cfg, qp, k_pos, scores.dtype)
    probs = jax.nn.softmax(scores, axis=-1).astype(qc.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


def attn_q_chunk(Sq, Sk):
    """Query-chunk size: bound the transient scores tensor (flash-style)."""
    if Sq * Sk <= 2**22:
        return Sq
    c = max(128, 2**20 // Sk)
    while Sq % c:
        c //= 2
    return max(c, 1)


def dense_attention(cfg, q, k, v, q_pos, k_pos):
    """softmax(q k^T / sqrt(hd) + mask) v with GQA head grouping.

    q (B,Sq,H,hd); k,v (B,Sk,KV,hd) -> (B,Sq,H,hd).
    Chunked over query rows with per-chunk remat so the S x S score matrix is
    never resident (the dense-phase memory baseline is flash-style, as any
    production TPU stack would be).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    c = attn_q_chunk(Sq, k.shape[1])
    if c == Sq:
        out = _attn_chunk(cfg, qg, k, v, q_pos, k_pos)
        return out.reshape(B, Sq, H, hd)
    nq = Sq // c
    qs = jnp.moveaxis(qg.reshape(B, nq, c, KV, G, hd), 1, 0)
    qps = q_pos.reshape(nq, c)

    @jax.checkpoint
    def one(args):
        qc, qp = args
        return _attn_chunk(cfg, qc, k, v, qp, k_pos)

    # scan (not lax.map) so the dry-run can unroll: a rolled body is counted
    # ONCE by cost_analysis, silently hiding (nq-1)/nq of the attention FLOPs
    _, out = jax.lax.scan(lambda _, x: (None, one(x)), None, (qs, qps),
                          unroll=min(cfg.scan_unroll, nq))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, hd)
    return out


def resolve_sparse_kernel(cfg, batch: int, kv_heads: int, *, nrb=None,
                          halo=None) -> str:
    """What `cfg.spion.kernel` dispatches to at trace time ("fused"/"jnp").

    Mesh-aware: under an active multi-device mesh (distributed.sharding.
    current_mesh()) "auto" picks the shard_map-wrapped fused kernel whenever
    at least one kernel dim shards — batch over the data axes, KV heads
    over 'model' (kernel_shard_axes), or Q row-blocks over 'seq' when the
    pattern halo fits (`nrb` row-blocks + the plan's static `halo` extents,
    kernel_seq_axis) — so sparse training keeps the Pallas kernel and its
    sparse backward on pods instead of reverting to jnp gathers. This mesh
    branch is deliberately NOT gated on the TPU backend: CI's
    virtual-device meshes and the dry-run must exercise the exact
    production dispatch (shard_map + kernel), accepting the Pallas
    interpreter's speed off-TPU — a real multi-host CPU/GPU deployment that
    wants wall-clock should force kernel="jnp". When nothing divides, or
    with no mesh on a non-TPU backend, "auto" falls back to the jnp BCSR
    path (the GSPMD-compatible gather stand-in). Exposed separately so
    dry-runs and tests can record the resolution without tracing a step."""
    impl = getattr(cfg.spion, "kernel", "auto")
    if impl != "auto":
        return impl
    mesh = current_mesh()
    if mesh is not None and mesh.size > 1:
        from repro.distributed.sharding import (kernel_seq_axis,
                                                kernel_shard_axes)
        baxes, kv_ax = kernel_shard_axes(mesh, batch, kv_heads)
        seq_ax, _ = kernel_seq_axis(mesh, nrb, halo)
        return "fused" if (baxes or kv_ax or seq_ax) else "jnp"
    # meshless: the fused kernel compiles through Mosaic only on TPU; with
    # multiple devices but no mesh there is nothing to shard over, so stay
    # on the jnp path (jit places it on the default device either way)
    on_tpu = jax.default_backend() == "tpu" and jax.device_count() == 1
    return "fused" if on_tpu else "jnp"


def spion_sparse_attention(cfg, q, k, v, spion_layer):
    """Sparse-phase attention for one layer's BCSR tables.

    spion_layer: {'col_idx': (nrb, K), 'nvalid': (nrb,), 'block': int} plus,
    when a host-built SparsityPlan is threaded through the step, the layer's
    precomputed transposed tables {'row_idx': (ncb, KT*), 'nvalid_t': (ncb,)}
    — the fused kernel's dK/dV backward grid then shrinks to the true
    pattern width KT* and the per-step under-jit bcsr_transpose disappears —
    and optionally the STATIC 'halo' (left, right) column-extent pair (plan
    stats), which unlocks 'seq'-axis sharding under a sequence-parallel
    mesh (DESIGN.md §10).
    Dispatch follows cfg.spion.kernel (see `resolve_sparse_kernel`): "auto"
    is mesh-aware — the fused differentiable Pallas kernel on single-device
    TPU AND, via the shard_map wrapper, under multi-device meshes whose
    axes divide the kernel dims; the pure-jnp BCSR path otherwise.
    "fused"/"jnp" force one (forcing "fused" under a mesh still routes
    through the shard_map wrapper; a bare kernel call there fails loudly —
    kernels/block_sparse_attn.py). Both paths train — the fused kernel's
    backward is sparse too, which is what makes the sparse phase's speedup
    honest for training, not just inference.
    """
    bcsr = BCSR(spion_layer["col_idx"], spion_layer["nvalid"],
                spion_layer["block"], q.shape[1])
    halo = spion_layer.get("halo")
    impl = resolve_sparse_kernel(cfg, q.shape[0], k.shape[2],
                                 nrb=q.shape[1] // spion_layer["block"],
                                 halo=halo)
    if impl == "fused":
        from repro.kernels.ops import spion_attention_kernel
        return spion_attention_kernel(cfg, q, k, v, bcsr, fused=True,
                                      row_idx=spion_layer.get("row_idx"),
                                      nvalid_t=spion_layer.get("nvalid_t"),
                                      halo=halo)
    return bcsr_attention(cfg, q, k, v, bcsr)


def attn_out(cfg, p, ctx):
    B, S = ctx.shape[:2]
    y = ctx.reshape(B, S, -1) @ p["wo"].astype(ctx.dtype)
    mode = getattr(cfg, "act_shard", None)
    if mode == "d":
        y = constrain(y, "batch", None, "model")
    elif mode == "seq":
        y = constrain(y, "batch", "model", None)
    else:
        y = constrain(y, "batch", None, None)
    if getattr(cfg, "ar_bf16", False):
        y = jax.lax.optimization_barrier(y)
    return y


def dense_mha(cfg, p, x, positions, kv_positions=None, xkv=None):
    """Full dense MHA block (self- or cross-attention)."""
    if xkv is None:
        q, k, v = qkv(cfg, p, x, positions)
        kp = positions
    else:  # cross-attention: q from x, k/v from xkv (no RoPE on cross in whisper)
        q, _, _ = qkv(cfg, p, x, positions)
        _, k, v = qkv(cfg, p, xkv, kv_positions)
        kp = kv_positions
    ctx = dense_attention(cfg, q, k, v, positions[0] if positions.ndim > 1 else positions,
                          kp[0] if kp.ndim > 1 else kp)
    return attn_out(cfg, p, ctx)


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

def decode_attention(cfg, q, k_cache, v_cache, pos, kpos=None):
    """One-token decode: q (B,1,H,hd); caches (B,S_cache,KV,hd); pos scalar
    (current token index). `kpos` gives the absolute position stored in each
    cache slot (defaults to arange — plain append cache). Sliding-window archs
    use a ring buffer: slot s holds token pos - ((pos - s) % W)."""
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    S = k_cache.shape[1]
    # NOTE (hillclimb A it2, refuted): forcing the attention einsums to
    # consume the hd-sharded cache (partial scores + psum) removed the
    # involuntary-remat copies but cost 6x flops and 10x collective bytes —
    # the per-layer cache reshard copy is the cheaper evil. See EXPERIMENTS.md.
    qg = q.reshape(B, KV, G, hd)
    k_cache = k_cache.astype(q.dtype)  # fp8 caches upcast for the MXU einsum
    v_cache = v_cache.astype(q.dtype)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache).astype(jnp.float32) / np.sqrt(hd)
    if kpos is None:
        kpos = jnp.arange(S)
    ok = (kpos >= 0) & (kpos <= pos)
    if cfg.sliding_window:
        ok &= kpos > pos - cfg.sliding_window
    scores = jnp.where(ok[None, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v_cache)
    return out.reshape(B, 1, H, hd)


def cache_slot(cfg, pos, cache_len):
    """Ring-buffer slot for the token at absolute position `pos`."""
    return pos % cache_len


def ring_kpos(pos, cache_len):
    """Absolute positions held by each ring-buffer slot at decode step `pos`
    (after inserting token `pos`): slot s -> pos - ((pos - s) mod cache_len)."""
    s = jnp.arange(cache_len)
    return pos - jnp.mod(pos - s, cache_len)


def update_cache(k_cache, v_cache, k_new, v_new, slot):
    """Insert one token's k/v at index `slot`. Caches (B,S,KV,hd); new (B,1,KV,hd)."""
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype), (0, slot, 0, 0))
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# SPION pattern capture: pooled diagonal-conv of A^s, streamed (exact Eq. 3+4)
# ---------------------------------------------------------------------------

def capture_pooled_scores(cfg, q, k, q_pos, k_pos, filt: jnp.ndarray, block: int):
    """Return (pooled, frob_sq):
      pooled  = avgpool_BxB( diagconv_F(A^s) ) of the *head-and-batch-averaged*
                attention probabilities, shape (L/B, L/B), streamed row-panel
                by row-panel so peak memory is O(panel x L), not O(L^2);
      frob_sq = sum(A^s ** 2) of the averaged scores (Eq. 2 transition term).

    Matches paper Eq. 3 (conv_out(i,j) = sum_f A(i+f, j+f) filter(f)) with
    zero padding, then Eq. 4 average pooling.
    """
    B_, Sq, H, hd = q.shape
    L = k.shape[1]
    F = int(filt.shape[0])
    nb = Sq // block
    KV = k.shape[2]
    G = H // KV

    panel = block  # one block-row of conv output per step; needs F halo rows
    # pad q rows by F so every dynamic_slice is in-bounds; padded rows are
    # masked to zero after the softmax (Eq. 3 zero padding).
    qp_ = jnp.pad(q, ((0, 0), (0, F), (0, 0), (0, 0)))
    qpos_ = jnp.concatenate([q_pos, q_pos[-1] + 1 + jnp.arange(F)])

    def probs_rows(r0, rows):
        """A^s rows [r0, r0+rows) averaged over batch+heads -> (rows, L)."""
        qs = jax.lax.dynamic_slice(qp_, (0, r0, 0, 0), (B_, rows, H, hd))
        qg = qs.reshape(B_, rows, KV, G, hd)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) / np.sqrt(hd)
        qpos = jax.lax.dynamic_slice(qpos_, (r0,), (rows,))
        s = s + _mask_bias(cfg, qpos, k_pos, s.dtype)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.mean(p, axis=(0, 1, 2))  # (rows, L)
        valid = (r0 + jnp.arange(rows)) < Sq
        return jnp.where(valid[:, None], p, 0.0)

    def one_block_row(I):
        r0 = I * block
        a = probs_rows(r0, panel + F)  # halo: conv row i needs A rows [i, i+F)
        frob = jnp.sum(a[:panel] ** 2)  # rows r0..r0+panel of A^s
        # conv_out rows r0..r0+block: sum_f w_f * A[r + f, cols shifted by f]
        def body(f, acc):
            w = filt[f]
            rowpanel = jax.lax.dynamic_slice(a, (f, 0), (panel, L))
            shifted = jax.lax.dynamic_slice(  # columns shifted left by f, zero fill
                jnp.pad(rowpanel, ((0, 0), (0, F))), (0, f), (panel, L))
            return acc + w * shifted
        conv = jax.lax.fori_loop(0, F, body, jnp.zeros((panel, L), jnp.float32))
        # average-pool this block row: (panel, L) -> (L/B,)
        return conv.reshape(block, nbk, block).mean(axis=(0, 2)), frob

    nbk = L // block
    out, frobs = jax.lax.map(one_block_row, jnp.arange(nb))
    return out, jnp.sum(frobs)  # (Sq/B, L/B), scalar
