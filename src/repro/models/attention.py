"""Attention: dense GQA (train/prefill), KV-cache decode (scalar or
PER-ROW positions — the continuous-batching engine decodes every cache slot
at its own offset), and the SPION pattern-capture path that streams pooled
diagonal-conv scores without ever materialising the L x L attention matrix
(DESIGN.md §2).

Sparse-phase execution is owned by core.attention_exec.SparseAttentionExec
(kernel resolution, plan tables, static block/halo — DESIGN.md §11);
`spion_sparse_attention` / `resolve_sparse_kernel` here are thin per-layer
wrappers kept for kernel tests and external callers.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models.layers import _he, linear, rope


class AttnParams(NamedTuple):
    pass  # attention params are plain dicts; NamedTuple kept out intentionally


def attn_init(key, cfg, dtype=jnp.float32, d=None):
    d = d or cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _he(ks[0], (d, cfg.num_heads * hd), d, dtype),
        "wk": _he(ks[1], (d, cfg.num_kv_heads * hd), d, dtype),
        "wv": _he(ks[2], (d, cfg.num_kv_heads * hd), d, dtype),
        "wo": _he(ks[3], (cfg.num_heads * hd, d), cfg.num_heads * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    return p


def qkv(cfg, p, x, positions):
    """x (B,S,d) -> q (B,S,H,hd), k/v (B,S,KV,hd), RoPE applied."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    # constrain on the merged head dim; GSPMD propagates through the reshape
    # (a 4-D heads constraint forces involuntary remat when H % |model| != 0)
    q = constrain(q, "batch", None, "model")
    k = constrain(k, "batch", None, "model")
    v = constrain(v, "batch", None, "model")
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.rope_theta:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask_bias(cfg, q_pos, k_pos, dtype):
    """additive mask (..., Sq, Sk): 0 allowed / -inf blocked."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if cfg.causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if cfg.sliding_window:
        ok &= q_pos[:, None] - k_pos[None, :] < cfg.sliding_window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def _attn_chunk(cfg, qc, k, v, qp, k_pos):
    """One query chunk: qc (B,c,KV,G,hd) vs full k/v -> (B,c,KV,G,hd)."""
    hd = qc.shape[-1]
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qc, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd) + _mask_bias(cfg, qp, k_pos, scores.dtype)
    probs = jax.nn.softmax(scores, axis=-1).astype(qc.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


def attn_q_chunk(Sq, Sk):
    """Query-chunk size: bound the transient scores tensor (flash-style)."""
    if Sq * Sk <= 2**22:
        return Sq
    c = max(128, 2**20 // Sk)
    while Sq % c:
        c //= 2
    return max(c, 1)


def dense_attention(cfg, q, k, v, q_pos, k_pos):
    """softmax(q k^T / sqrt(hd) + mask) v with GQA head grouping.

    q (B,Sq,H,hd); k,v (B,Sk,KV,hd) -> (B,Sq,H,hd).
    Chunked over query rows with per-chunk remat so the S x S score matrix is
    never resident (the dense-phase memory baseline is flash-style, as any
    production TPU stack would be).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    c = attn_q_chunk(Sq, k.shape[1])
    if c == Sq:
        out = _attn_chunk(cfg, qg, k, v, q_pos, k_pos)
        return out.reshape(B, Sq, H, hd)
    nq = Sq // c
    qs = jnp.moveaxis(qg.reshape(B, nq, c, KV, G, hd), 1, 0)
    qps = q_pos.reshape(nq, c)

    @jax.checkpoint
    def one(args):
        qc, qp = args
        return _attn_chunk(cfg, qc, k, v, qp, k_pos)

    # scan (not lax.map) so the dry-run can unroll: a rolled body is counted
    # ONCE by cost_analysis, silently hiding (nq-1)/nq of the attention FLOPs
    _, out = jax.lax.scan(lambda _, x: (None, one(x)), None, (qs, qps),
                          unroll=min(cfg.scan_unroll, nq))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, hd)
    return out


def resolve_sparse_kernel(cfg, batch: int, kv_heads: int, *, nrb=None,
                          halo=None) -> str:
    """What `cfg.spion.kernel` dispatches to at trace time ("fused"/"jnp").

    Thin wrapper over core.attention_exec.resolve_kernel — the
    SparseAttentionExec owns the resolution (mesh-aware "auto": shard_map
    fused under multi-device meshes, jnp BCSR otherwise; see its docstring).
    Kept here because dry-runs and tests record the resolution without
    tracing a step."""
    from repro.core.attention_exec import resolve_kernel
    return resolve_kernel(cfg, batch, kv_heads, nrb=nrb, halo=halo)


def spion_sparse_attention(cfg, q, k, v, spion_layer):
    """Sparse-phase attention for one layer's BCSR tables.

    spion_layer: {'col_idx': (nrb, K), 'nvalid': (nrb,), 'block': int} plus,
    when a host-built SparsityPlan is threaded through the step, the layer's
    precomputed transposed tables {'row_idx': (ncb, KT*), 'nvalid_t': (ncb,)}
    and optionally the STATIC 'halo' (left, right) column-extent pair.

    Legacy per-layer entry point: builds a single-layer SparseAttentionExec
    (core/attention_exec.py — the single owner of kernel resolution and the
    static block/halo metadata) and runs its `attend`. Model families thread
    the exec itself; this wrapper exists for kernel tests and external
    callers that hold one layer's tables in hand.
    """
    from repro.core.attention_exec import SparseAttentionExec
    ex = SparseAttentionExec.coerce(spion_layer)
    return ex.attend(cfg, q, k, v, spion_layer)


def attn_out(cfg, p, ctx):
    B, S = ctx.shape[:2]
    y = ctx.reshape(B, S, -1) @ p["wo"].astype(ctx.dtype)
    mode = getattr(cfg, "act_shard", None)
    if mode == "d":
        y = constrain(y, "batch", None, "model")
    elif mode == "seq":
        y = constrain(y, "batch", "model", None)
    else:
        y = constrain(y, "batch", None, None)
    if getattr(cfg, "ar_bf16", False):
        y = jax.lax.optimization_barrier(y)
    return y


def dense_mha(cfg, p, x, positions, kv_positions=None, xkv=None):
    """Full dense MHA block (self- or cross-attention)."""
    if xkv is None:
        q, k, v = qkv(cfg, p, x, positions)
        kp = positions
    else:  # cross-attention: q from x, k/v from xkv (no RoPE on cross in whisper)
        q, _, _ = qkv(cfg, p, x, positions)
        _, k, v = qkv(cfg, p, xkv, kv_positions)
        kp = kv_positions
    ctx = dense_attention(cfg, q, k, v, positions[0] if positions.ndim > 1 else positions,
                          kp[0] if kp.ndim > 1 else kp)
    return attn_out(cfg, p, ctx)


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

def decode_positions(pos, batch: int):
    """Normalise a decode position argument — a scalar (every batch row at
    the same position, the legacy synchronous form) or a (B,) vector (the
    serving engine's per-slot positions) — to a (B,) int32 vector."""
    p = jnp.atleast_1d(jnp.asarray(pos))
    return jnp.broadcast_to(p, (batch,)).astype(jnp.int32)


def decode_attention(cfg, q, k_cache, v_cache, pos, kpos=None):
    """One-token decode: q (B,1,H,hd); caches (B,S_cache,KV,hd); pos scalar
    or (B,) per-row current token indices (continuous batching decodes every
    slot at its own offset). `kpos` gives the absolute position stored in
    each cache slot, (S,) or (B,S) (defaults to arange — plain append
    cache). Sliding-window archs use a ring buffer: slot s holds token
    pos - ((pos - s) % W)."""
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    S = k_cache.shape[1]
    # NOTE (hillclimb A it2, refuted): forcing the attention einsums to
    # consume the hd-sharded cache (partial scores + psum) removed the
    # involuntary-remat copies but cost 6x flops and 10x collective bytes —
    # the per-layer cache reshard copy is the cheaper evil. See EXPERIMENTS.md.
    posb = decode_positions(pos, B)
    qg = q.reshape(B, KV, G, hd)
    k_cache = k_cache.astype(q.dtype)  # fp8 caches upcast for the MXU einsum
    v_cache = v_cache.astype(q.dtype)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache).astype(jnp.float32) / np.sqrt(hd)
    if kpos is None:
        kpos = jnp.arange(S)
    kpos = jnp.broadcast_to(kpos, (B, S))
    ok = (kpos >= 0) & (kpos <= posb[:, None])
    if cfg.sliding_window:
        ok &= kpos > posb[:, None] - cfg.sliding_window
    scores = jnp.where(ok[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v_cache)
    return out.reshape(B, 1, H, hd)


def paged_decode_attention(cfg, q, kp, vp, layer, pos, page_table, *,
                           page: int):
    """Dense one-token decode over a paged KV pool (core.kv_pool): gather
    layer `layer`'s mapped pages through the page table, flatten to the
    contiguous (B, S, KV, hd) layout, and reuse `decode_attention` with
    per-position kpos. kp/vp (L, num_pages, page, KV, hd); page_table
    (B, NB) of physical page ids, -1 = unmapped (those positions get
    kpos=-1 and are masked; the gather clamps them to the scratch page).

    Sliding-window rings store position p in table slot (p//page) % NB, so
    kpos must be per-position ring arithmetic (`ring_kpos`), not
    block-granular — within the active page, offsets past pos % page still
    hold the PREVIOUS rotation's tokens. Where every block is mapped this
    is bitwise-identical to the contiguous dense decode (same flattened
    values, same kpos, same ops)."""
    B = q.shape[0]
    NB = page_table.shape[1]
    KV, hd = kp.shape[3], kp.shape[4]
    S = NB * page
    posb = decode_positions(pos, B)
    phys = jnp.maximum(page_table, 0)
    kflat = kp[layer, phys].reshape(B, S, KV, hd)
    vflat = vp[layer, phys].reshape(B, S, KV, hd)
    if cfg.sliding_window:
        base = ring_kpos(posb, S)
    else:
        base = jnp.broadcast_to(jnp.arange(S), (B, S))
    kpos = jnp.where(jnp.repeat(page_table >= 0, page, axis=1), base, -1)
    out = decode_attention(cfg, q, kflat, vflat, posb, kpos=kpos)
    # a fully-unmapped row (reclaimed serving slot parked on the scratch
    # page) softmaxes over all -inf -> NaN; that NaN would be scattered into
    # the SHARED scratch page next layer and 0*NaN-poison every other row's
    # clamped gathers. Force such rows to zero context (mapped rows pick
    # their already-computed value — bitwise-neutral).
    any_ok = jnp.any(page_table >= 0, axis=1)
    return jnp.where(any_ok[:, None, None, None], out, 0.0)


def cache_slot(cfg, pos, cache_len):
    """Ring-buffer slot for the token at absolute position `pos` (scalar or
    per-row vector)."""
    return pos % cache_len


def ring_kpos(pos, cache_len):
    """Absolute positions held by each ring-buffer slot at decode step `pos`
    (after inserting token `pos`): slot s -> pos - ((pos - s) mod cache_len).
    pos scalar -> (cache_len,); pos (B,) -> (B, cache_len)."""
    s = jnp.arange(cache_len)
    if jnp.ndim(pos) == 0:
        return pos - jnp.mod(pos - s, cache_len)
    p = jnp.asarray(pos)[:, None]
    return p - jnp.mod(p - s, cache_len)


def update_cache(k_cache, v_cache, k_new, v_new, slot):
    """Insert one token's k/v at index `slot`. Caches (B,S,KV,hd); new
    (B,1,KV,hd). `slot` scalar writes every row at the same index (the
    legacy synchronous decode); a (B,) vector writes each row at its own
    slot — the continuous-batching engine's per-slot positions, and the
    reason one slot's decode can never touch another slot's cache row."""
    slot = jnp.asarray(slot)
    if slot.ndim == 0:
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new.astype(k_cache.dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new.astype(v_cache.dtype), (0, slot, 0, 0))
        return k_cache, v_cache
    rows = jnp.arange(k_cache.shape[0])
    k_cache = k_cache.at[rows, slot].set(k_new[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[rows, slot].set(v_new[:, 0].astype(v_cache.dtype))
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# SPION pattern capture: pooled diagonal-conv of A^s, streamed (exact Eq. 3+4)
# ---------------------------------------------------------------------------

def capture_pooled_scores(cfg, q, k, q_pos, k_pos, filt: jnp.ndarray, block: int):
    """Return (pooled, frob_sq):
      pooled  = avgpool_BxB( diagconv_F(A^s) ) of the *head-and-batch-averaged*
                attention probabilities, shape (L/B, L/B), streamed row-panel
                by row-panel so peak memory is O(panel x L), not O(L^2);
      frob_sq = sum(A^s ** 2) of the averaged scores (Eq. 2 transition term).

    Matches paper Eq. 3 (conv_out(i,j) = sum_f A(i+f, j+f) filter(f)) with
    zero padding, then Eq. 4 average pooling.
    """
    B_, Sq, H, hd = q.shape
    L = k.shape[1]
    F = int(filt.shape[0])
    nb = Sq // block
    KV = k.shape[2]
    G = H // KV

    panel = block  # one block-row of conv output per step; needs F halo rows
    # pad q rows by F so every dynamic_slice is in-bounds; padded rows are
    # masked to zero after the softmax (Eq. 3 zero padding).
    qp_ = jnp.pad(q, ((0, 0), (0, F), (0, 0), (0, 0)))
    qpos_ = jnp.concatenate([q_pos, q_pos[-1] + 1 + jnp.arange(F)])

    def probs_rows(r0, rows):
        """A^s rows [r0, r0+rows) averaged over batch+heads -> (rows, L)."""
        qs = jax.lax.dynamic_slice(qp_, (0, r0, 0, 0), (B_, rows, H, hd))
        qg = qs.reshape(B_, rows, KV, G, hd)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) / np.sqrt(hd)
        qpos = jax.lax.dynamic_slice(qpos_, (r0,), (rows,))
        s = s + _mask_bias(cfg, qpos, k_pos, s.dtype)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.mean(p, axis=(0, 1, 2))  # (rows, L)
        valid = (r0 + jnp.arange(rows)) < Sq
        return jnp.where(valid[:, None], p, 0.0)

    def one_block_row(I):
        r0 = I * block
        a = probs_rows(r0, panel + F)  # halo: conv row i needs A rows [i, i+F)
        frob = jnp.sum(a[:panel] ** 2)  # rows r0..r0+panel of A^s
        # conv_out rows r0..r0+block: sum_f w_f * A[r + f, cols shifted by f]
        def body(f, acc):
            w = filt[f]
            rowpanel = jax.lax.dynamic_slice(a, (f, 0), (panel, L))
            shifted = jax.lax.dynamic_slice(  # columns shifted left by f, zero fill
                jnp.pad(rowpanel, ((0, 0), (0, F))), (0, f), (panel, L))
            return acc + w * shifted
        conv = jax.lax.fori_loop(0, F, body, jnp.zeros((panel, L), jnp.float32))
        # average-pool this block row: (panel, L) -> (L/B,)
        return conv.reshape(block, nbk, block).mean(axis=(0, 2)), frob

    nbk = L // block
    out, frobs = jax.lax.map(one_block_row, jnp.arange(nb))
    return out, jnp.sum(frobs)  # (Sq/B, L/B), scalar
