"""zamba2 hybrid: Mamba2 backbone + ONE shared attention block applied every
k-th layer (weight sharing across applications — the zamba trick). SPION
applies to the shared attention block only; each *application* gets its own
layer-wise pattern (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.attention_exec import SparseAttentionExec
from repro.core.kv_pool import PagedKVCache, scatter_token, write_target
from repro.distributed.sharding import constrain
from repro.models import attention as A
from repro.models import layers as Lyr
from repro.models import mamba as M


def n_attn_apps(cfg):
    k = cfg.hybrid_attn_every
    return cfg.num_layers // k if k else 0


def init(key, cfg):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    lkeys = jax.random.split(ks[0], cfg.num_layers)

    def layer_init(k):
        return {
            "norm": Lyr.rmsnorm_init(cfg.d_model, jnp.float32),
            "mamba": M.mamba_init(k, cfg, dtype),
        }

    shared = {
        "attn_norm": Lyr.rmsnorm_init(cfg.d_model, jnp.float32),
        "attn": A.attn_init(ks[1], cfg, dtype=dtype),
        "mlp_norm": Lyr.rmsnorm_init(cfg.d_model, jnp.float32),
        "mlp": Lyr.mlp_init(ks[2], cfg, dtype=dtype),
    }
    return {
        "tok_embed": Lyr.embed_init(ks[3], cfg.vocab_size, cfg.d_model, dtype),
        "layers": jax.vmap(layer_init)(lkeys),
        "shared_attn": shared,
        "final_norm": Lyr.rmsnorm_init(cfg.d_model, jnp.float32),
        "lm_head": Lyr.embed_init(ks[4], cfg.vocab_size, cfg.d_model, dtype),
    }


def _shared_attn_block(cfg, sp, h, positions, ex, app_idx, capture):
    """`ex` is the SparseAttentionExec (None -> dense); the shared block's
    tables are indexed by the traced application index, not scanned."""
    x = Lyr.rmsnorm(sp["attn_norm"], h.astype(jnp.float32)).astype(h.dtype)
    q, k, v = A.qkv(cfg, sp["attn"], x, positions)
    cap = jnp.zeros((), jnp.float32)
    if capture is not None:
        cap = A.capture_pooled_scores(cfg, q, k, positions, positions,
                                      capture["filt"], capture["block"])
    if ex is not None:
        ctx = ex.attend_app(cfg, q, k, v, app_idx)
    else:
        ctx = A.dense_attention(cfg, q, k, v, positions, positions)
    h = h + A.attn_out(cfg, sp["attn"], ctx)
    x = Lyr.rmsnorm(sp["mlp_norm"], h.astype(jnp.float32)).astype(h.dtype)
    return h + Lyr.mlp(cfg, sp["mlp"], x), cap


def forward(params, cfg, batch, *, spion=None, capture=None):
    dtype = jnp.dtype(cfg.dtype)
    ex = SparseAttentionExec.coerce(spion)
    h = Lyr.embed(params["tok_embed"], batch["tokens"], dtype)
    h = constrain(h, "batch", None, None)
    S = h.shape[1]
    positions = jnp.arange(S)
    every = cfg.hybrid_attn_every
    shared = params["shared_attn"]

    def body(carry, xs):
        h, app = carry
        lp, idx = xs

        def run(h, lp):
            x = Lyr.rmsnorm(lp["norm"], h.astype(jnp.float32)).astype(h.dtype)
            y, _ = M.mamba_apply(cfg, lp["mamba"], x)
            return h + y
        if cfg.remat:
            run = jax.checkpoint(run, prevent_cse=False)
        h = run(h, lp)

        is_attn = (idx % every) == (every - 1)

        def with_attn(h):
            return _shared_attn_block(cfg, shared, h, positions, ex, app, capture)

        def without(h):
            if capture is not None:
                nb = S // capture["block"]
                return h, (jnp.zeros((nb, nb), jnp.float32), jnp.zeros((), jnp.float32))
            return h, jnp.zeros((), jnp.float32)

        h, cap = jax.lax.cond(is_attn, with_attn, without, h)
        app = app + jnp.where(is_attn, 1, 0)
        return (h, app), cap

    (h, _), caps = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.int32)),
        (params["layers"], jnp.arange(cfg.num_layers)), unroll=cfg.scan_unroll)
    h = Lyr.rmsnorm(params["final_norm"], h.astype(jnp.float32)).astype(dtype)
    logits = Lyr.unembed(params["lm_head"], h)
    aux = {"captured": caps} if capture is not None else {}
    return constrain(logits, "batch", None, "model"), aux


# -- decode ------------------------------------------------------------------

def init_cache(cfg, batch_size, max_len, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    napps = n_attn_apps(cfg)
    hd = cfg.resolved_head_dim
    st = M.init_state(cfg, batch_size)
    return {
        "conv": jnp.stack([st["conv"]] * cfg.num_layers),
        "ssm": jnp.stack([st["ssm"]] * cfg.num_layers),
        "k": jnp.zeros((napps, batch_size, max_len, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((napps, batch_size, max_len, cfg.num_kv_heads, hd), dtype),
    }


def decode_step(params, cfg, cache, tokens, pos, *, spion=None):
    """pos scalar or (B,) per-row positions; `spion` (exec or payload)
    makes each shared-attention application decode over only its pattern
    row's cache blocks (per-app tables, indexed like the forward).

    Paged form: cache {"conv", "ssm", "kv": core.kv_pool.PagedKVCache} —
    the shared block's per-application K/V live in a page pool whose layer
    axis is the application index, while the recurrent conv/ssm states stay
    contiguous (fixed-size, no paging win)."""
    paged = isinstance(cache, dict) and isinstance(cache.get("kv"),
                                                   PagedKVCache)
    dtype = jnp.dtype(cfg.dtype)
    ex = SparseAttentionExec.coerce(spion, phase="decode")
    h = Lyr.embed(params["tok_embed"], tokens, dtype)
    every = cfg.hybrid_attn_every
    shared = params["shared_attn"]
    posb = A.decode_positions(pos, tokens.shape[0])
    positions = posb[:, None]
    napps = n_attn_apps(cfg)
    if paged:
        pkv = cache["kv"]
        pt = pkv.pt
        phys_w, off_w = write_target(pt, posb, pkv.page, ring=False)

    # mamba layers scanned; attention caches updated by app index
    def body(carry, xs):
        h, app, kall, vall = carry
        lp, conv_st, ssm_st, idx = xs
        x = Lyr.rmsnorm(lp["norm"], h.astype(jnp.float32)).astype(h.dtype)
        y, st = M.mamba_apply(cfg, lp["mamba"], x, state={"conv": conv_st, "ssm": ssm_st})
        h = h + y
        is_attn = (idx % every) == (every - 1)

        def with_attn(operand):
            h, kall, vall = operand
            x = Lyr.rmsnorm(shared["attn_norm"], h.astype(jnp.float32)).astype(h.dtype)
            q, k_new, v_new = A.qkv(cfg, shared["attn"], x, positions)
            if paged:
                kall, vall = scatter_token(kall, vall, app, k_new, v_new,
                                           phys_w, off_w)
                if ex is not None:
                    ctx = ex.decode_paged_app(cfg, q, kall, vall, app, posb,
                                              pt)
                else:
                    ctx = A.paged_decode_attention(cfg, q, kall, vall, app,
                                                   posb, pt, page=pkv.page)
            else:
                kc = jnp.take(kall, app, axis=0)
                vc = jnp.take(vall, app, axis=0)
                kc, vc = A.update_cache(kc, vc, k_new, v_new, posb)
                if ex is not None:
                    ctx = ex.decode_app(cfg, q, kc, vc, posb, app)
                else:
                    ctx = A.decode_attention(cfg, q, kc, vc, posb)
            h = h + A.attn_out(cfg, shared["attn"], ctx)
            x = Lyr.rmsnorm(shared["mlp_norm"], h.astype(jnp.float32)).astype(h.dtype)
            h = h + Lyr.mlp(cfg, shared["mlp"], x)
            if not paged:
                kall = jax.lax.dynamic_update_index_in_dim(kall, kc, app, 0)
                vall = jax.lax.dynamic_update_index_in_dim(vall, vc, app, 0)
            return h, kall, vall

        if napps > 0:  # static: reduced 1-layer configs have no attn apps
            h, kall, vall = jax.lax.cond(is_attn, with_attn, lambda o: o,
                                         (h, kall, vall))
            app = app + jnp.where(is_attn, 1, 0)
        return (h, app, kall, vall), (st["conv"], st["ssm"])

    if paged:
        kv0, vv0 = pkv.kp, pkv.vp
    else:
        kv0, vv0 = cache["k"], cache["v"]
    carry = (h, jnp.zeros((), jnp.int32), kv0, vv0)
    (h, _, kall, vall), (convs, ssms) = jax.lax.scan(
        body, carry, (params["layers"], cache["conv"], cache["ssm"], jnp.arange(cfg.num_layers)),
        unroll=cfg.scan_unroll)
    h = Lyr.rmsnorm(params["final_norm"], h.astype(jnp.float32)).astype(dtype)
    logits = Lyr.unembed(params["lm_head"], h)[:, 0]
    if paged:
        return logits, {"conv": convs, "ssm": ssms,
                        "kv": PagedKVCache(kall, vall, pt, page=pkv.page)}
    return logits, {"conv": convs, "ssm": ssms, "k": kall, "v": vall}
