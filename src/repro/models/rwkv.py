"""RWKV6 (Finch) — attention-free, data-dependent per-channel decay.

WKV6 recurrence per head (K = V = head_dim):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t @ S_{t-1} + ((r_t * u) . k_t) v_t
Chunked-parallel implementation; every exponent is a *difference* of decay
cumsums and therefore <= 0 (numerically safe without clamping tricks).
SPION is inapplicable (no attention-score matrix) — see DESIGN.md §4.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as Lyr
from repro.models.layers import _he

LORA_R = 64


def timemix_init(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.num_heads
    hd = cfg.resolved_head_dim
    inner = H * hd
    ks = jax.random.split(key, 8)
    return {
        "mix": jnp.full((4, d), 0.5, jnp.float32),  # r,k,v,w token-shift mixes
        "w_r": _he(ks[0], (d, inner), d, dtype),
        "w_k": _he(ks[1], (d, inner), d, dtype),
        "w_v": _he(ks[2], (d, inner), d, dtype),
        "w_g": _he(ks[3], (d, inner), d, dtype),
        "out_proj": _he(ks[4], (inner, d), inner, dtype),
        "w0": jnp.full((inner,), -6.0, jnp.float32),        # base log-log decay
        "w_lora_a": _he(ks[5], (d, LORA_R), d, jnp.float32),
        "w_lora_b": (jax.random.normal(ks[6], (LORA_R, inner)) * 0.01).astype(jnp.float32),
        "u": (jax.random.normal(ks[7], (H, hd)) * 0.1).astype(jnp.float32),  # bonus
        "ln_x": Lyr.layernorm_init(inner, jnp.float32),
    }


def channelmix_init(key, cfg, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2)
    return {
        "mix": jnp.full((1, d), 0.5, jnp.float32),
        "w_in": _he(ks[0], (d, ff), d, dtype),
        "w_out": _he(ks[1], (ff, d), ff, dtype),
    }


def token_shift(x):
    """previous token along seq (zero for t=0): (B,S,d) -> (B,S,d)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _mix(x, xs, m):
    return x * m + xs * (1 - m)


def wkv6_chunked(r, k, v, a, u, chunk, unroll=1):
    """r,k: (B,S,H,K); v: (B,S,H,V); a = log decay (B,S,H,K) (<= 0);
    u: (H,K) bonus. Returns y (B,S,H,V).

    Chunk-PARALLEL form: all O(S*C*K) intra-chunk math is batched over the
    chunk axis (real, countable HLO ops; fast compiles); only the tiny
    O(n*H*K*V) state combine is a sequential scan.
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    C = min(chunk, S)
    n = S // C
    rc = r.reshape(B, n, C, H, K)
    kc = k.reshape(B, n, C, H, K)
    vc = v.reshape(B, n, C, H, V)
    ac = a.reshape(B, n, C, H, K).astype(jnp.float32)

    cum = jnp.cumsum(ac, axis=2)                       # inclusive (B,n,C,H,K)
    excl = cum - ac                                    # exclusive
    last = cum[:, :, -1]                               # (B,n,H,K)

    # parallel over chunks: per-chunk state delta + decay
    k_dec = kc * jnp.exp(last[:, :, None] - cum).astype(kc.dtype)
    delta = jnp.einsum("bnshk,bnshv->bnhkv", k_dec, vc).astype(jnp.float32)
    decay = jnp.exp(last)                              # (B,n,H,K)

    # sequential state combine (cheap): S_{j+1} = decay_j * S_j + delta_j
    def comb(S_in, x):
        d, dl = x
        return S_in * d[..., None] + dl, S_in          # emit the INCOMING state

    S0 = jnp.zeros((B, H, K, V), jnp.float32)
    xs = (jnp.swapaxes(decay, 0, 1), jnp.swapaxes(delta, 0, 1))
    _, S_in = jax.lax.scan(comb, S0, xs)
    S_in = jnp.swapaxes(S_in, 0, 1)                    # (B,n,H,K,V)

    # parallel: inter-chunk contribution
    r_dec = rc * jnp.exp(excl).astype(rc.dtype)
    y_inter = jnp.einsum("bnthk,bnhkv->bnthv", r_dec, S_in.astype(rc.dtype))

    # parallel: intra-chunk M_ts = sum_k r_tk k_sk exp(excl_t - cum_s), s < t
    D = excl[:, :, :, None] - cum[:, :, None, :]       # (B,n,C,C,H,K), <=0 s<t
    mask = (jnp.arange(C)[:, None] > jnp.arange(C)[None, :])[None, None, :, :, None, None]
    expD = jnp.where(mask, jnp.exp(jnp.minimum(D, 0.0)), 0.0).astype(rc.dtype)
    M = jnp.einsum("bnthk,bnshk,bntshk->bntsh", rc, kc, expD)
    diag = jnp.einsum("bnthk,bnthk,hk->bnth", rc, kc, u.astype(rc.dtype))
    y_intra = jnp.einsum("bntsh,bnshv->bnthv", M, vc) + diag[..., None] * vc

    return (y_inter + y_intra).reshape(B, S, H, V)


def timemix_apply(cfg, p, x, state=None, pos=None):
    """x (B,S,d). state: None (train) or dict(prev (B,d), S (B,H,K,V)) for
    decode (S=1). Returns (y, new_state)."""
    B, S, d = x.shape
    H = cfg.num_heads
    hd = cfg.resolved_head_dim
    if state is None:
        xs = token_shift(x)
    else:
        xs = state["prev"][:, None, :].astype(x.dtype)
    m = p["mix"].astype(x.dtype)
    xr, xk, xv, xw = (_mix(x, xs, m[i]) for i in range(4))
    r = (xr @ p["w_r"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (xk @ p["w_k"].astype(x.dtype)).reshape(B, S, H, hd)
    v = (xv @ p["w_v"].astype(x.dtype)).reshape(B, S, H, hd)
    g = jax.nn.silu(_mix(x, xs, m[0]) @ p["w_g"].astype(x.dtype))
    r = constrain(r, "batch", None, "model", None)
    # data-dependent decay (lora), a = -exp(.) clamped to [-8, -1e-6]
    wlog = p["w0"] + (jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"]) @ p["w_lora_b"])
    a = -jnp.exp(wlog).reshape(B, S, H, hd)
    a = jnp.clip(a, -8.0, -1e-6)
    u = p["u"]
    if state is None:
        y = wkv6_chunked(r, k, v, a, u, cfg.ssm.chunk, unroll=cfg.scan_unroll)
        new_state = None
    else:
        S_in = state["S"]  # (B,H,K,V)
        r1, k1, v1, a1 = r[:, 0], k[:, 0], v[:, 0], a[:, 0]
        y = jnp.einsum("bhk,bhkv->bhv", r1.astype(jnp.float32), S_in) + \
            jnp.einsum("bhk,hk,bhk,bhv->bhv", r1.astype(jnp.float32), u, k1.astype(jnp.float32), v1.astype(jnp.float32))
        y = y[:, None].astype(x.dtype)
        S_new = S_in * jnp.exp(a1)[..., None] + \
            jnp.einsum("bhk,bhv->bhkv", k1.astype(jnp.float32), v1.astype(jnp.float32))
        new_state = {"prev": x[:, -1].astype(jnp.float32), "S": S_new}
    y = y.reshape(B, S, H * hd)
    y = Lyr.layernorm(p["ln_x"], y.astype(jnp.float32)).astype(x.dtype) * g
    return y @ p["out_proj"].astype(x.dtype), new_state


def channelmix_apply(cfg, p, x, state=None):
    xs = token_shift(x) if state is None else state["prev"][:, None, :].astype(x.dtype)
    xk = _mix(x, xs, p["mix"][0].astype(x.dtype))
    h = jnp.square(jax.nn.relu(xk @ p["w_in"].astype(x.dtype)))
    h = constrain(h, "batch", None, "model")
    y = h @ p["w_out"].astype(x.dtype)
    new_state = None if state is None else {"prev": x[:, -1].astype(jnp.float32)}
    return y, new_state


def layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "tm_norm": Lyr.layernorm_init(cfg.d_model, jnp.float32),
        "tm": timemix_init(ks[0], cfg, dtype),
        "cm_norm": Lyr.layernorm_init(cfg.d_model, jnp.float32),
        "cm": channelmix_init(ks[1], cfg, dtype),
    }


def init(key, cfg):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    lkeys = jax.random.split(ks[0], cfg.num_layers)
    return {
        "tok_embed": Lyr.embed_init(ks[1], cfg.vocab_size, cfg.d_model, dtype),
        "in_norm": Lyr.layernorm_init(cfg.d_model, jnp.float32),
        "layers": jax.vmap(lambda k: layer_init(k, cfg, dtype))(lkeys),
        "final_norm": Lyr.layernorm_init(cfg.d_model, jnp.float32),
        "lm_head": Lyr.embed_init(ks[2], cfg.vocab_size, cfg.d_model, dtype),
    }


def forward(params, cfg, batch, *, spion=None, capture=None):
    dtype = jnp.dtype(cfg.dtype)
    h = Lyr.embed(params["tok_embed"], batch["tokens"], dtype)
    h = Lyr.layernorm(params["in_norm"], h.astype(jnp.float32)).astype(dtype)
    h = constrain(h, "batch", None, None)

    def body(h, lp):
        def run(h, lp):
            y, _ = timemix_apply(cfg, lp["tm"], Lyr.layernorm(lp["tm_norm"], h.astype(jnp.float32)).astype(h.dtype))
            h2 = h + y
            y2, _ = channelmix_apply(cfg, lp["cm"], Lyr.layernorm(lp["cm_norm"], h2.astype(jnp.float32)).astype(h.dtype))
            return h2 + y2
        if cfg.remat:
            run = jax.checkpoint(run, prevent_cse=False)
        return run(h, lp), jnp.zeros(())

    h, _ = jax.lax.scan(body, h, params["layers"], unroll=cfg.scan_unroll)
    h = Lyr.layernorm(params["final_norm"], h.astype(jnp.float32)).astype(dtype)
    logits = Lyr.unembed(params["lm_head"], h)
    return constrain(logits, "batch", None, "model"), {}


def init_cache(cfg, batch_size, max_len, dtype=None):
    """Recurrent state: O(1) in sequence length (the SSM long-context win)."""
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    L, B, d = cfg.num_layers, batch_size, cfg.d_model
    return {
        "tm_prev": jnp.zeros((L, B, d), jnp.float32),
        "cm_prev": jnp.zeros((L, B, d), jnp.float32),
        "S": jnp.zeros((L, B, H, hd, hd), jnp.float32),
    }


def decode_step(params, cfg, cache, tokens, pos, *, spion=None):
    # `pos` is accepted (scalar or per-row vector) for signature uniformity
    # but unused: the recurrent state is position-free, which is exactly the
    # O(1)-per-token long-context property.
    if spion is not None:
        raise NotImplementedError(
            "rwkv (family 'ssm') keeps recurrent state, not an attention KV "
            "cache — there is nothing for a sparsity plan to gather. Check "
            "registry.build(cfg).supports_sparse_decode before constructing "
            "a sparse serve step (launch.steps.make_serve_step and "
            "launch.serve.ServeEngine do) and serve this family densely "
            "(spion=None). This raise is a trace-time backstop only.")
    dtype = jnp.dtype(cfg.dtype)
    h = Lyr.embed(params["tok_embed"], tokens, dtype)
    h = Lyr.layernorm(params["in_norm"], h.astype(jnp.float32)).astype(dtype)

    def body(h, xs):
        lp, tm_prev, cm_prev, S = xs
        xin = Lyr.layernorm(lp["tm_norm"], h.astype(jnp.float32)).astype(h.dtype)
        y, st = timemix_apply(cfg, lp["tm"], xin, state={"prev": tm_prev, "S": S})
        h = h + y
        xin2 = Lyr.layernorm(lp["cm_norm"], h.astype(jnp.float32)).astype(h.dtype)
        y2, st2 = channelmix_apply(cfg, lp["cm"], xin2, state={"prev": cm_prev})
        # note: token-shift states must hold the *inputs* to each mix
        return h + y2, (xin[:, -1].astype(jnp.float32), xin2[:, -1].astype(jnp.float32), st["S"])

    h, (tm_prev, cm_prev, S) = jax.lax.scan(
        body, h, (params["layers"], cache["tm_prev"], cache["cm_prev"], cache["S"]),
        unroll=cfg.scan_unroll)
    h = Lyr.layernorm(params["final_norm"], h.astype(jnp.float32)).astype(dtype)
    logits = Lyr.unembed(params["lm_head"], h)[:, 0]
    return logits, {"tm_prev": tm_prev, "cm_prev": cm_prev, "S": S}
