"""Uniform model API over all families.

build(cfg) -> ModelBundle with:
    init(key) -> params
    forward(params, batch, *, spion=None, capture=None) -> (logits, aux)
    loss(params, batch, *, spion=None, capture=None) -> (loss, aux)
    init_cache(batch_size, max_len) -> cache
    decode_step(params, cache, tokens, pos, *, spion=None) -> (logits, cache)
        pos: scalar or (B,) per-row positions; spion: a decode-phase
        SparseAttentionExec (or legacy payload) for pattern-bounded sparse
        decode on the attention families
    prefill_kv(params, batch, *, spion=None) -> (logits, ks, vs) — the fused
        serving prefill (full-sequence forward that also emits per-layer
        RoPE'd K/V for cache insertion); None for families without a plain
        KV cache (ssm/hybrid serve via stepwise prefill instead)
input_specs(cfg, shape) -> ShapeDtypeStruct pytrees for the dry-run
(train/prefill: kwargs of forward-batch; decode: (cache, tokens, pos)).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import encdec, hybrid, rwkv, transformer

MOE_LB_WEIGHT = 0.01
MOE_Z_WEIGHT = 1e-3


class ModelBundle(NamedTuple):
    cfg: ModelConfig
    init: Callable
    forward: Callable
    loss: Callable
    init_cache: Callable
    decode_step: Callable
    prefill_kv: Optional[Callable] = None
    # capability flags, checked at engine/step construction (not deep in a
    # layer scan): the ssm family keeps recurrent state, so there is no
    # attention KV cache to sparsify or page
    supports_sparse_decode: bool = True
    supports_paged_cache: bool = True


def _family_module(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm", "encoder"):
        return transformer
    if cfg.family == "ssm":
        return rwkv
    if cfg.family == "hybrid":
        return hybrid
    if cfg.family in ("audio", "encdec"):
        return encdec
    raise ValueError(cfg.family)


def cross_entropy(logits, labels, mask=None):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def build(cfg: ModelConfig) -> ModelBundle:
    mod = _family_module(cfg)

    def init(key):
        return mod.init(key, cfg)

    def forward(params, batch, *, spion=None, capture=None):
        return mod.forward(params, cfg, batch, spion=spion, capture=capture)

    def loss(params, batch, *, spion=None, capture=None):
        logits, aux = forward(params, batch, spion=spion, capture=capture)
        labels = batch["labels"]
        if cfg.num_patch_tokens and "patch_embeds" in batch:
            # VLM: logits cover [patch, text]; loss over text positions only
            logits = logits[:, cfg.num_patch_tokens:]
        mask = batch.get("loss_mask")
        l = cross_entropy(logits, labels, mask)
        if cfg.moe is not None and "lb_loss" in aux:
            l = l + MOE_LB_WEIGHT * aux["lb_loss"] + MOE_Z_WEIGHT * aux["z_loss"]
        return l, aux

    def init_cache(batch_size, max_len, **kw):
        return mod.init_cache(cfg, batch_size, max_len, **kw)

    def decode_step(params, cache, tokens, pos, *, spion=None):
        return mod.decode_step(params, cfg, cache, tokens, pos, spion=spion)

    prefill_kv = None
    if hasattr(mod, "prefill_step"):
        def prefill_kv(params, batch, *, spion=None):
            return mod.prefill_step(params, cfg, batch, spion=spion)

    has_kv = cfg.family != "ssm"
    return ModelBundle(cfg, init, forward, loss, init_cache, decode_step,
                       prefill_kv, supports_sparse_decode=has_kv,
                       supports_paged_cache=has_kv)


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct; no allocation)
# ---------------------------------------------------------------------------

def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Returns {'batch': ...} for train/prefill or
    {'cache': ..., 'tokens': ..., 'pos': ...} for decode shapes."""
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family in ("audio", "encdec"):
            batch = {
                "frames": _sd((B, S, cfg.d_model), cfg.dtype),
                "tokens": _sd((B, S), tok),
                "labels": _sd((B, S), tok),
            }
        elif cfg.family == "vlm":
            S_text = S - cfg.num_patch_tokens
            batch = {
                "tokens": _sd((B, S_text), tok),
                "patch_embeds": _sd((B, cfg.num_patch_tokens, cfg.d_model), cfg.dtype),
                "labels": _sd((B, S_text), tok),
            }
        else:
            batch = {"tokens": _sd((B, S), tok), "labels": _sd((B, S), tok)}
        if shape.kind == "prefill":
            batch.pop("labels")
        return {"batch": batch}
    # decode: one new token against a KV cache / state of length S
    bundle_cache = cache_specs(cfg, B, S)
    return {
        "cache": bundle_cache,
        "tokens": _sd((B, 1), tok),
        "pos": _sd((), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, B: int, S: int):
    hd = cfg.resolved_head_dim
    if cfg.family == "ssm":
        H = cfg.num_heads
        L, d = cfg.num_layers, cfg.d_model
        return {
            "tm_prev": _sd((L, B, d), jnp.float32),
            "cm_prev": _sd((L, B, d), jnp.float32),
            "S": _sd((L, B, H, hd, hd), jnp.float32),
        }
    if cfg.family == "hybrid":
        from repro.models.mamba import CONV_W, NGROUPS
        ssm = cfg.ssm
        inner = ssm.expand * cfg.d_model
        H = inner // ssm.head_dim
        conv_dim = inner + 2 * NGROUPS * ssm.state_size
        napps = hybrid.n_attn_apps(cfg)
        return {
            "conv": _sd((cfg.num_layers, B, CONV_W - 1, conv_dim), jnp.float32),
            "ssm": _sd((cfg.num_layers, B, H, ssm.state_size, ssm.head_dim), jnp.float32),
            "k": _sd((napps, B, S, cfg.num_kv_heads, hd), cfg.cache_dtype or cfg.dtype),
            "v": _sd((napps, B, S, cfg.num_kv_heads, hd), cfg.cache_dtype or cfg.dtype),
        }
    cdt = cfg.cache_dtype or cfg.dtype
    if cfg.family in ("audio", "encdec"):
        L = cfg.num_layers
        # SWA-like bound is not applicable; cross K/V at encoder length = S
        return {
            "k": _sd((L, B, S, cfg.num_kv_heads, hd), cdt),
            "v": _sd((L, B, S, cfg.num_kv_heads, hd), cdt),
            "ck": _sd((L, B, S, cfg.num_kv_heads, hd), cdt),
            "cv": _sd((L, B, S, cfg.num_kv_heads, hd), cdt),
        }
    L = cfg.num_layers
    S_eff = min(S, cfg.sliding_window) if cfg.sliding_window else S
    return {
        "k": _sd((L, B, S_eff, cfg.num_kv_heads, hd), cdt),
        "v": _sd((L, B, S_eff, cfg.num_kv_heads, hd), cdt),
    }


def params_specs(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of params via eval_shape (no allocation)."""
    bundle = build(cfg)
    return jax.eval_shape(lambda: bundle.init(jax.random.key(0)))
