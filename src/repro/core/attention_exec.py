"""SparseAttentionExec — the single owner of one resolved sparse-attention
execution (DESIGN.md §11).

Before this existed, the sparse-phase state was threaded per-callsite: the
BCSR/SparsityPlan arrays rode the step as a raw dict, the STATIC block/halo
scalars were re-closed-over by every step builder, and the kernel resolution
lived in models/attention while the dispatch statics lived in kernels/ops —
four places that had to agree. The exec centralises all of it:

  - `tables`  — the SparsityPlan array payload (col_idx / nvalid and, when
    plan-built, row_idx / nvalid_t), TRACED: they are step inputs.
  - `block`, `halo`, `phase`, `kernel`, `kernel_config` — STATIC metadata,
    carried as pytree aux_data. Passing an exec through `jax.jit` therefore
    keys the trace on them automatically: a new plan with a different halo
    (or a different autotuned kernel config) retraces the step without any
    caller-side bookkeeping (launch/train.Trainer used to track the halo by
    hand to know when to rebuild its jitted sparse step). `kernel_config`
    is the autotuner's per-pattern scheduling pick (kernels/autotune.py),
    resolved from the on-disk cache at construction when the tables are
    concrete — so both the training step and the serve engine hit tuned
    configs simply by building their exec outside jit.

`phase` is "train" | "prefill" | "decode". Train and prefill share
`attend()` (full-sequence block-sparse attention, fused-Pallas or jnp per
`resolve_kernel`); decode uses `decode()` — the pattern-bounded KV-cache
gather (core.sparse_attention.sparse_decode_attention) that turns the
layer-wise pattern into an inference win: the query position's row-block
selects a bounded set of cache column blocks, and only those are read.

The exec is registered as a pytree, so it can be a jitted-step argument, a
lax.scan can carry its stacked tables (`scan_tables()`), and sharding-spec
trees map over it leaf-wise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sparse_attention import (BCSR, PLAN_TABLE_KEYS,
                                         bcsr_attention,
                                         paged_sparse_decode_attention,
                                         sparse_decode_attention)

_PHASES = ("train", "prefill", "decode")


def resolve_kernel(cfg, batch: int, kv_heads: int, *, nrb=None, halo=None,
                   prefer=None) -> str:
    """What the sparse phase dispatches to at trace time ("fused"/"jnp").

    Mesh-aware: under an active multi-device mesh (distributed.sharding.
    current_mesh()) "auto" picks the shard_map-wrapped fused kernel whenever
    at least one kernel dim shards — batch over the data axes, KV heads
    over 'model' (kernel_shard_axes), or Q row-blocks over 'seq' when the
    pattern halo fits (`nrb` row-blocks + the plan's static `halo` extents,
    kernel_seq_axis) — so sparse training keeps the Pallas kernel and its
    sparse backward on pods instead of reverting to jnp gathers. This mesh
    branch is deliberately NOT gated on the TPU backend: CI's
    virtual-device meshes and the dry-run must exercise the exact
    production dispatch (shard_map + kernel), accepting the Pallas
    interpreter's speed off-TPU — a real multi-host CPU/GPU deployment that
    wants wall-clock should force kernel="jnp". When nothing divides, or
    with no mesh on a non-TPU backend, "auto" falls back to the jnp BCSR
    path (the GSPMD-compatible gather stand-in). `prefer` overrides
    cfg.spion.kernel (an exec pinned to one impl). Exposed separately so
    dry-runs and tests can record the resolution without tracing a step."""
    impl = prefer or getattr(cfg.spion, "kernel", "auto")
    if impl != "auto":
        return impl
    from repro.distributed.sharding import current_mesh
    mesh = current_mesh()
    if mesh is not None and mesh.size > 1:
        from repro.distributed.sharding import (kernel_seq_axis,
                                                kernel_shard_axes)
        baxes, kv_ax = kernel_shard_axes(mesh, batch, kv_heads)
        seq_ax, _ = kernel_seq_axis(mesh, nrb, halo)
        return "fused" if (baxes or kv_ax or seq_ax) else "jnp"
    # meshless: "auto" takes the compiled kernel lane only where the
    # Mosaic port exists today (TPU, single device; with multiple devices
    # but no mesh there is nothing to shard over). GPU counts as a
    # compiled backend in kernels/dispatch (no silent interpreter), but
    # the prefetch-grid kernels have not been ported to Triton yet, so
    # "auto" stays on jnp there — an explicit kernel="fused" engages the
    # Triton lane and fails loudly if lowering is unsupported.
    from repro.kernels.dispatch import compiled_backend
    on_tpu = compiled_backend() == "tpu" and jax.device_count() == 1
    return "fused" if on_tpu else "jnp"


@jax.tree_util.register_pytree_node_class
class SparseAttentionExec:
    """One phase's resolved sparse-attention execution. See module docstring.

    Construct via `coerce` (normalises the legacy tables-dict payload, an
    existing exec, or None), `from_plan` (a SparsityPlan), or directly with
    stacked arrays. `tables` values are stacked (Ly, ...) for the
    scan-over-layers model families; `attend`/`decode` consume the
    PER-LAYER slices the scan hands back (they read only the exec's static
    metadata, never `self.tables`, so closing the exec over a scan body
    does not haul the stacked arrays into every layer)."""

    def __init__(self, tables, *, block, halo=None, phase="train",
                 kernel=None, kernel_config=None):
        if phase not in _PHASES:
            raise ValueError(f"phase must be one of {_PHASES}, got {phase!r}")
        self.tables = {k: tables[k] for k in PLAN_TABLE_KEYS
                       if tables is not None and tables.get(k) is not None}
        self.block = int(block)
        self.halo = None if halo is None else (int(halo[0]), int(halo[1]))
        self.phase = phase
        self.kernel = kernel  # None -> defer to cfg.spion.kernel
        # the autotune cache is consulted HERE, once per exec construction
        # (kernels/autotune.py): a pure on-disk lookup keyed by the pattern
        # digest, never a sweep. The resolved KernelConfig rides the pytree
        # aux (static), so every jitted step using this exec — training and
        # serving alike — hits the tuned schedule without retracing per
        # step. Construction under jit (tracer tables, e.g. the legacy
        # dict payload crossing launch/steps._coerce_step_tables) skips
        # the lookup: no digest of a tracer, config stays as given.
        if kernel_config is None and self.tables and \
                not any(isinstance(v, jax.core.Tracer)
                        for v in self.tables.values()):
            from repro.kernels.autotune import lookup
            kernel_config = lookup(self.tables, self.block)
        self.kernel_config = kernel_config

    # -- pytree protocol (tables traced; everything else static) -------------

    def tree_flatten(self):
        keys = tuple(k for k in PLAN_TABLE_KEYS if k in self.tables)
        children = tuple(self.tables[k] for k in keys)
        return children, (keys, self.block, self.halo, self.phase,
                          self.kernel, self.kernel_config)

    @classmethod
    def tree_unflatten(cls, aux, children):
        keys, block, halo, phase, kernel, kernel_config = aux
        ex = cls.__new__(cls)
        ex.tables = dict(zip(keys, children))
        ex.block, ex.halo, ex.phase, ex.kernel = block, halo, phase, kernel
        ex.kernel_config = kernel_config
        return ex

    def __repr__(self):
        shapes = {k: getattr(v, "shape", None) for k, v in self.tables.items()}
        return (f"SparseAttentionExec(phase={self.phase!r}, block={self.block}, "
                f"halo={self.halo}, kernel={self.kernel!r}, "
                f"kernel_config={self.kernel_config!r}, tables={shapes})")

    # -- constructors ---------------------------------------------------------

    @classmethod
    def coerce(cls, spion, *, phase=None, kernel=None, kernel_config=None):
        """None | exec | tables-dict payload -> exec (or None).

        The dict form is the historical `spion=` payload: stacked (or
        per-layer) arrays plus a static int 'block' and optionally the
        static 'halo' pair. The int leaves must be concrete — a dict that
        crossed a jit boundary has tracer ints; convert to an exec BEFORE
        jitting (launch/steps does) or pass the exec itself through jit."""
        if spion is None:
            return None
        if isinstance(spion, cls):
            if phase is not None and spion.phase != phase:
                return cls(spion.tables, block=spion.block, halo=spion.halo,
                           phase=phase, kernel=kernel or spion.kernel,
                           kernel_config=kernel_config or spion.kernel_config)
            return spion
        return cls(spion, block=spion["block"], halo=spion.get("halo"),
                   phase=phase or "train", kernel=kernel,
                   kernel_config=kernel_config)

    @classmethod
    def from_plan(cls, plan, *, phase="train", kernel=None,
                  kernel_config=None):
        """From a core.sparse_attention.SparsityPlan (halo from its stats)."""
        return cls(plan.tables, block=plan.tables["block"],
                   halo=plan.stats.get("halo"), phase=phase, kernel=kernel,
                   kernel_config=kernel_config)

    # -- table views ----------------------------------------------------------

    def scan_tables(self):
        """Stacked per-layer arrays to ride a lax.scan over layers. Decode
        needs only the forward BCSR (the query row selects its column
        blocks); train/prefill also carry the plan's transposed tables for
        the fused dK/dV backward grid."""
        keys = ("col_idx", "nvalid") if self.phase == "decode" \
            else PLAN_TABLE_KEYS
        return {k: self.tables[k] for k in keys if k in self.tables}

    def layer(self, idx):
        """Per-layer (or per-app) slice of the stacked tables — for callers
        that index by a traced layer id (the hybrid shared-attention block)
        instead of scanning."""
        keys = self.scan_tables()
        return {k: jnp.take(v, idx, axis=0) for k, v in keys.items()}

    # -- execution ------------------------------------------------------------

    def attend(self, cfg, q, k, v, layer_tables):
        """Sparse train/prefill attention for ONE layer.

        layer_tables: this layer's slices of `scan_tables()` —
        col_idx (nrb, K), nvalid (nrb,), optionally row_idx/nvalid_t.
        Dispatch follows `resolve_kernel` (mesh-aware "auto"): the fused
        differentiable Pallas kernel — through the shard_map wrapper under
        a multi-device mesh — or the pure-jnp BCSR path. Both paths train:
        the fused kernel's backward is sparse too, which is what makes the
        sparse phase's speedup honest for training, not just inference."""
        bcsr = BCSR(layer_tables["col_idx"], layer_tables["nvalid"],
                    self.block, q.shape[1])
        impl = resolve_kernel(cfg, q.shape[0], k.shape[2],
                              nrb=q.shape[1] // self.block, halo=self.halo,
                              prefer=self.kernel)
        if impl == "fused":
            from repro.kernels.ops import spion_attention_kernel
            return spion_attention_kernel(cfg, q, k, v, bcsr,
                                          row_idx=layer_tables.get("row_idx"),
                                          nvalid_t=layer_tables.get("nvalid_t"),
                                          halo=self.halo,
                                          config=self.kernel_config)
        return bcsr_attention(cfg, q, k, v, bcsr)

    def attend_app(self, cfg, q, k, v, app_idx):
        """`attend` for the hybrid family's shared attention block: the
        stacked tables are indexed by the (traced) application index, not
        scanned."""
        return self.attend(cfg, q, k, v, self.layer(app_idx))

    def decode(self, cfg, q, k_cache, v_cache, pos, layer_tables, *,
               ring=False):
        """Sparse one-token decode for ONE layer: gather and attend over
        only the cache blocks this query position's pattern row lists
        (core.sparse_attention.sparse_decode_attention — same Alg. 6
        zero-corrected softmax as the sparse prefill, so decode logits
        match the prefill row). `pos` may be per-batch-row (B,). ring=True
        for sliding-window ring-buffer caches."""
        return sparse_decode_attention(
            cfg, q, k_cache, v_cache, pos, layer_tables["col_idx"],
            layer_tables["nvalid"], block=self.block, ring=ring)

    def decode_app(self, cfg, q, k_cache, v_cache, pos, app_idx, *,
                   ring=False):
        return self.decode(cfg, q, k_cache, v_cache, pos, self.layer(app_idx),
                           ring=ring)

    def decode_paged(self, cfg, q, kp, vp, layer, pos, page_table,
                     layer_tables, *, ring=False):
        """`decode` over a paged KV pool (core.kv_pool.PagedKVCache): the
        pattern's column blocks resolve through the request's page-table
        row, so the O(K*block) cache gather is pure page indirection. The
        pool's page size must equal the plan block — the alignment that
        makes pattern block ids and page-table coordinates the same thing.
        kp/vp are the (L, num_pages, block, KV, hd) pool arrays, `layer`
        the traced pool layer index, page_table (B, NB)."""
        if kp.shape[2] != self.block:
            raise ValueError(
                f"paged decode: pool page size {kp.shape[2]} != plan block "
                f"{self.block}; build the pool with page == block")
        return paged_sparse_decode_attention(
            cfg, q, kp, vp, layer, pos, page_table,
            layer_tables["col_idx"], layer_tables["nvalid"],
            page=self.block, ring=ring)

    def decode_paged_app(self, cfg, q, kp, vp, app_idx, pos, page_table, *,
                         ring=False):
        return self.decode_paged(cfg, q, kp, vp, app_idx, pos, page_table,
                                 self.layer(app_idx), ring=ring)

    # -- introspection --------------------------------------------------------

    @property
    def coverage(self) -> int:
        """Sequence positions the pattern tables cover (nrb * block)."""
        return int(self.tables["col_idx"].shape[-2]) * self.block
