"""SPION three-phase training controller (paper Alg. 2 / Fig. 2).

Phases:  dense  --(Frobenius criterion)-->  pattern generation  -->  sparse.

The controller is host-side state; the jitted step only sees (a) a `capture`
kwarg during the dense phase and (b) the SparsityPlan tables during the
sparse phase. Pattern generation runs once, on rank-0, between epochs; the
plan (forward BCSR + transposed tables padded to the true column-population
width KT*, all tiny int32) is broadcast as step inputs — no scaling cliff at
1000+ nodes (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import SpionConfig
from repro.core.pattern import diagonal_filter, generate_pattern
from repro.core.sparse_attention import (PLAN_TABLE_KEYS, bcsr_from_blockmask,
                                         build_sparsity_plan)


@dataclass
class SpionState:
    phase: str = "dense"                     # "dense" | "sparse"
    epoch: int = 0
    frob_hist: List[np.ndarray] = field(default_factory=list)   # per-epoch (Ly,)
    dist_hist: List[float] = field(default_factory=list)
    tables: Optional[dict] = None            # SparsityPlan payload for the step
    density: Optional[float] = None
    plan_stats: Optional[dict] = None        # host-only occupancy stats

    def to_py(self, include_tables: bool = True):
        """JSON-safe dict. With include_tables=False the (potentially large)
        plan arrays are left out — pass them via `table_arrays()` to a binary
        store (checkpoint extra_arrays) and hand them back to `from_py`."""
        d = {
            "phase": self.phase,
            "epoch": self.epoch,
            "frob_hist": [h.tolist() for h in self.frob_hist],
            "dist_hist": list(self.dist_hist),
            "density": self.density,
            "plan_stats": self.plan_stats,
        }
        if self.tables is None:
            d["tables"] = None
        elif include_tables:
            d["tables"] = {k: np.asarray(self.tables[k]).tolist()
                           for k in PLAN_TABLE_KEYS if k in self.tables}
            d["tables"]["block"] = int(self.tables["block"])
        else:
            d["tables_meta"] = {"block": int(self.tables["block"])}
        return d

    def table_arrays(self):
        """Plan arrays as numpy, for binary persistence (None in dense phase)."""
        if self.tables is None:
            return None
        return {k: np.asarray(self.tables[k])
                for k in PLAN_TABLE_KEYS if k in self.tables}

    @staticmethod
    def from_py(d, arrays: Optional[dict] = None):
        st = SpionState(phase=d["phase"], epoch=d["epoch"],
                        dist_hist=list(d["dist_hist"]), density=d.get("density"),
                        plan_stats=d.get("plan_stats"))
        st.frob_hist = [np.asarray(h) for h in d["frob_hist"]]
        tab = d.get("tables")
        meta = d.get("tables_meta")
        if arrays and not (tab or meta):
            # plan arrays were supplied but the state dict promises no
            # tables at all — a mismatched (state JSON, binary arrays) pair.
            # Silently dropping the arrays here used to make a sparse-phase
            # resume train dense forever; fail loudly instead.
            raise ValueError(
                "SpionState.from_py: plan arrays were supplied but the "
                "state dict has neither 'tables' nor 'tables_meta' — the "
                "checkpoint state JSON and its binary extra_arrays do not "
                "belong together (or the state was saved pre-plan). Restore "
                "the matching pair, or pass arrays=None to resume dense.")
        if arrays and (tab or meta):
            st.tables = {k: jnp.asarray(np.asarray(arrays[k], np.int32))
                         for k in PLAN_TABLE_KEYS if k in arrays}
            st.tables["block"] = int((tab or meta)["block"])
        elif meta and not tab:
            # tables_meta promises binary plan arrays; resuming without them
            # would silently run the sparse phase with tables=None (dense
            # steps forever) — fail loudly instead
            raise ValueError(
                "SpionState.from_py: state has tables_meta but no plan "
                "arrays were supplied (checkpoint extra_arrays missing or "
                "unreadable)")
        elif tab:
            st.tables = {k: jnp.asarray(np.asarray(tab[k], np.int32))
                         for k in PLAN_TABLE_KEYS if k in tab}
            st.tables["block"] = int(tab["block"])
        if st.tables is not None and "row_idx" not in st.tables:
            # legacy (pre-plan) checkpoint: rebuild the transposed tables
            # host-side ONCE here, not silently per-step under jit
            plan = build_sparsity_plan(st.tables["col_idx"],
                                       st.tables["nvalid"],
                                       st.tables["block"])
            st.tables = plan.tables
            st.plan_stats = plan.stats
        return st


def plan_digest(arrays: Optional[dict], block) -> str:
    """Digest of a plan's table arrays + block size — the value
    assert_in_sync compares across processes after a broadcast or a
    checkpoint restore (divergent plans must fail loudly, DESIGN.md §12)."""
    from repro.distributed import runtime
    return runtime.payload_digest(arrays or {}, {"block": int(block)})


class SpionController:
    def __init__(self, spion_cfg: SpionConfig, *, causal: bool, seq_len: int):
        self.cfg = spion_cfg
        self.causal = causal
        self.seq_len = seq_len
        self.filt = jnp.asarray(diagonal_filter(spion_cfg.conv_filter_size), jnp.float32)

    # -- jitted-step kwargs ---------------------------------------------------

    def capture_kwargs(self, state: SpionState):
        """`capture=` kwarg for forward() during the dense phase (else None)."""
        if not self.cfg.enabled or state.phase != "dense":
            return None
        return {"filt": self.filt, "block": self.cfg.block_size}

    def spion_kwargs(self, state: SpionState):
        """`spion=` kwarg for forward() during the sparse phase (else None).

        Gated on cfg.enabled, not just the state: a checkpoint captured in
        the sparse phase but restored under a SPION-disabled config still
        carries `state.tables`, and injecting them would silently keep the
        step sparse against the operator's explicit config."""
        if (self.cfg.enabled and state.phase == "sparse"
                and state.tables is not None):
            return state.tables
        return None

    def attention_exec(self, state: SpionState, phase: str = "train"):
        """The sparse phase's SparseAttentionExec (None in the dense phase
        or when SPION is disabled — same gating as `spion_kwargs`).

        The exec is the single owner of the plan arrays AND the static
        block/halo metadata (core/attention_exec.py): passed straight into
        a jitted step, its statics ride the pytree aux_data, so a new
        plan's halo retraces the step without the trainer tracking it.
        `phase="decode"` yields the serving engine's sparse-decode exec
        from the same training plan — the train -> serve handoff is one
        constructor call."""
        tables = self.spion_kwargs(state)
        if tables is None:
            return None
        from repro.core.attention_exec import SparseAttentionExec
        halo = (state.plan_stats or {}).get("halo")
        return SparseAttentionExec(tables, block=tables["block"], halo=halo,
                                   phase=phase)

    def verify_plan_sync(self, state: SpionState, tag: str = "spion_plan_restore"):
        """Multi-process: assert every process holds the SAME plan (digest
        over tables + block). Called after a checkpoint restore — each
        process reads the checkpoint independently, and a torn read or a
        mixed-up checkpoint dir on one host must not let that host train
        through a different sparsity pattern. No-op single-process or in
        the dense phase."""
        from repro.distributed import runtime
        if runtime.process_count() <= 1 or state.tables is None:
            return
        runtime.assert_in_sync(
            tag, plan_digest(state.table_arrays(), state.tables["block"]))

    # -- per-epoch update (paper Alg. 2 lines 7-12) ----------------------------

    def observe_epoch(self, state: SpionState, pooled: np.ndarray,
                      frob_sq: np.ndarray) -> SpionState:
        """pooled: (Ly, nb, nb) streamed conv+pool capture; frob_sq: (Ly,).
        Returns the updated state; generates patterns on transition."""
        if not self.cfg.enabled or state.phase == "sparse":
            state.epoch += 1
            return state
        frob = np.sqrt(np.maximum(np.asarray(frob_sq, np.float64), 0.0))
        state.frob_hist.append(frob)
        if len(state.frob_hist) >= 2:
            # Eq. 2: distance_i = | ||A_{i-1}||_F - ||A_i||_F |, layer-averaged
            d = float(np.mean(np.abs(state.frob_hist[-2] - state.frob_hist[-1])))
            state.dist_hist.append(d)
        transition = False
        if len(state.dist_hist) >= 2 and state.epoch + 1 >= self.cfg.min_dense_epochs:
            # Alg. 2 line 10: sqrt((d_{i-1} - d_i)^2) < alpha
            transition = abs(state.dist_hist[-2] - state.dist_hist[-1]) < self.cfg.transition_tol
        if state.epoch + 1 >= self.cfg.max_dense_epochs:
            transition = True
        if transition:
            state = self.generate(state, pooled)
        state.epoch += 1
        return state

    def generate(self, state: SpionState, pooled: np.ndarray) -> SpionState:
        """Pattern generation for every layer; builds the full SparsityPlan:
        stacked padded BCSR plus the transposed tables at the true max
        column population KT* (host-side, once — the fused VJP's dK/dV grid
        then runs (N, ncb, KT*, G) with no per-step transpose).

        Single-controller in a multi-process job (DESIGN.md §12): the
        flood-fill runs ONLY on process 0 and the plan arrays are broadcast
        to every process through a device collective, followed by a digest
        check — N processes flood-filling independently is N chances for a
        float tie-break to diverge, and two hosts running different
        sparsity patterns through the kernels would corrupt training
        silently. The digest check turns that failure mode into a loud
        crash."""
        from repro.distributed import runtime
        if runtime.process_count() > 1:
            if runtime.is_coordinator():
                state = self._generate_local(state, pooled)
                arrays = state.table_arrays()
                meta = {"block": int(state.tables["block"]),
                        "plan_stats": state.plan_stats,
                        "density": state.density}
            else:
                arrays, meta = None, None
            arrays, meta = runtime.broadcast_arrays(arrays, meta)
            runtime.assert_in_sync(
                "spion_plan", plan_digest(arrays, meta["block"]))
            state.tables = {k: jnp.asarray(np.asarray(v, np.int32))
                            for k, v in arrays.items()}
            state.tables["block"] = int(meta["block"])
            state.plan_stats = meta["plan_stats"]
            state.density = meta["density"]
            state.phase = "sparse"
            return state
        return self._generate_local(state, pooled)

    def _generate_local(self, state: SpionState, pooled: np.ndarray) -> SpionState:
        pooled = np.asarray(pooled, np.float64)
        Ly = pooled.shape[0]
        masks = [
            generate_pattern(None, pooled=pooled[l], variant=self.cfg.variant,
                             block_size=self.cfg.block_size,
                             alpha_quantile=self.cfg.alpha_quantile,
                             causal=self.causal)
            for l in range(Ly)
        ]
        K = self.cfg.max_blocks_per_row or max(int(m.sum(axis=1).max()) for m in masks)
        tabs = [bcsr_from_blockmask(m, self.cfg.block_size, max_k=K) for m in masks]
        plan = build_sparsity_plan(
            np.stack([np.asarray(t.col_idx) for t in tabs]),
            np.stack([np.asarray(t.nvalid) for t in tabs]),
            self.cfg.block_size)
        state.tables = plan.tables
        state.plan_stats = plan.stats
        state.density = float(np.mean([m.mean() for m in masks]))
        state.phase = "sparse"
        return state
