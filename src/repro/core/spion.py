"""SPION three-phase training controller (paper Alg. 2 / Fig. 2).

Phases:  dense  --(Frobenius criterion)-->  pattern generation  -->  sparse.

The controller is host-side state; the jitted step only sees (a) a `capture`
kwarg during the dense phase and (b) stacked BCSR tables during the sparse
phase. Pattern generation runs once, on rank-0, between epochs, and the tiny
BCSR tables (K * L/B int32 per layer) are broadcast as step inputs — no
scaling cliff at 1000+ nodes (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import SpionConfig
from repro.core.pattern import diagonal_filter, generate_pattern
from repro.core.sparse_attention import bcsr_from_blockmask


@dataclass
class SpionState:
    phase: str = "dense"                     # "dense" | "sparse"
    epoch: int = 0
    frob_hist: List[np.ndarray] = field(default_factory=list)   # per-epoch (Ly,)
    dist_hist: List[float] = field(default_factory=list)
    tables: Optional[dict] = None            # stacked BCSR for the jitted step
    density: Optional[float] = None

    def to_py(self):
        return {
            "phase": self.phase,
            "epoch": self.epoch,
            "frob_hist": [h.tolist() for h in self.frob_hist],
            "dist_hist": list(self.dist_hist),
            "density": self.density,
            "tables": None if self.tables is None else {
                "col_idx": np.asarray(self.tables["col_idx"]).tolist(),
                "nvalid": np.asarray(self.tables["nvalid"]).tolist(),
                "block": int(self.tables["block"]),
            },
        }

    @staticmethod
    def from_py(d):
        st = SpionState(phase=d["phase"], epoch=d["epoch"],
                        dist_hist=list(d["dist_hist"]), density=d.get("density"))
        st.frob_hist = [np.asarray(h) for h in d["frob_hist"]]
        if d.get("tables"):
            st.tables = {
                "col_idx": jnp.asarray(np.asarray(d["tables"]["col_idx"], np.int32)),
                "nvalid": jnp.asarray(np.asarray(d["tables"]["nvalid"], np.int32)),
                "block": int(d["tables"]["block"]),
            }
        return st


class SpionController:
    def __init__(self, spion_cfg: SpionConfig, *, causal: bool, seq_len: int):
        self.cfg = spion_cfg
        self.causal = causal
        self.seq_len = seq_len
        self.filt = jnp.asarray(diagonal_filter(spion_cfg.conv_filter_size), jnp.float32)

    # -- jitted-step kwargs ---------------------------------------------------

    def capture_kwargs(self, state: SpionState):
        """`capture=` kwarg for forward() during the dense phase (else None)."""
        if not self.cfg.enabled or state.phase != "dense":
            return None
        return {"filt": self.filt, "block": self.cfg.block_size}

    def spion_kwargs(self, state: SpionState):
        """`spion=` kwarg for forward() during the sparse phase (else None)."""
        if state.phase == "sparse" and state.tables is not None:
            return state.tables
        return None

    # -- per-epoch update (paper Alg. 2 lines 7-12) ----------------------------

    def observe_epoch(self, state: SpionState, pooled: np.ndarray,
                      frob_sq: np.ndarray) -> SpionState:
        """pooled: (Ly, nb, nb) streamed conv+pool capture; frob_sq: (Ly,).
        Returns the updated state; generates patterns on transition."""
        if not self.cfg.enabled or state.phase == "sparse":
            state.epoch += 1
            return state
        frob = np.sqrt(np.maximum(np.asarray(frob_sq, np.float64), 0.0))
        state.frob_hist.append(frob)
        if len(state.frob_hist) >= 2:
            # Eq. 2: distance_i = | ||A_{i-1}||_F - ||A_i||_F |, layer-averaged
            d = float(np.mean(np.abs(state.frob_hist[-2] - state.frob_hist[-1])))
            state.dist_hist.append(d)
        transition = False
        if len(state.dist_hist) >= 2 and state.epoch + 1 >= self.cfg.min_dense_epochs:
            # Alg. 2 line 10: sqrt((d_{i-1} - d_i)^2) < alpha
            transition = abs(state.dist_hist[-2] - state.dist_hist[-1]) < self.cfg.transition_tol
        if state.epoch + 1 >= self.cfg.max_dense_epochs:
            transition = True
        if transition:
            state = self.generate(state, pooled)
        state.epoch += 1
        return state

    def generate(self, state: SpionState, pooled: np.ndarray) -> SpionState:
        """Pattern generation for every layer; builds stacked padded BCSR."""
        pooled = np.asarray(pooled, np.float64)
        Ly = pooled.shape[0]
        masks = [
            generate_pattern(None, pooled=pooled[l], variant=self.cfg.variant,
                             block_size=self.cfg.block_size,
                             alpha_quantile=self.cfg.alpha_quantile,
                             causal=self.causal)
            for l in range(Ly)
        ]
        K = self.cfg.max_blocks_per_row or max(int(m.sum(axis=1).max()) for m in masks)
        tabs = [bcsr_from_blockmask(m, self.cfg.block_size, max_k=K) for m in masks]
        state.tables = {
            "col_idx": jnp.stack([t.col_idx for t in tabs]),
            "nvalid": jnp.stack([t.nvalid for t in tabs]),
            "block": self.cfg.block_size,
        }
        state.density = float(np.mean([m.mean() for m in masks]))
        state.phase = "sparse"
        return state
