"""Paged KV cache: a shared page pool + per-request page tables (DESIGN.md §14).

The serve engine's PR 5 caches were per-slot contiguous `max_len` strips, so
slot count was capped by worst-case memory and the decode floor was the layer
scan's full cache write-out (each tick rewrote every slot's whole strip
through the scan ys). This module replaces the representation:

  - `PagePool` owns ONE device pool of K/V pages shaped
    (layers, num_pages, page, kv_heads, head_dim) — a page is a cross-layer
    group, so a single (B, num_blocks) page table serves every layer of the
    scan — plus the host-side bookkeeping: a free list, per-page refcounts,
    and a chained-hash prefix registry for copy-on-write prompt sharing.
  - `PagedKVCache` is the traced view a decode step consumes: the pool's
    k/v arrays and a page table, registered as a pytree (arrays traced, page
    geometry static) so it rides `jax.jit` with donation like the old dict
    cache did.
  - decode writes become an O(B) scatter into the active page
    (`scatter_token`), carried through the layer scan as CARRY instead of
    scanned ys — the full cache write-out disappears.

Page size equals the BCSR block when serving sparsely, so the sparse decode
gather (core.sparse_attention.paged_sparse_decode_attention) is pure page
indirection: pattern column block -> page table -> physical page.

Page 0 is reserved scratch: it is never allocated, unmapped page-table
entries (-1) clamp to it, and idle serve slots park their per-tick writes
there. Reads through unmapped entries are position-masked, so scratch junk
never reaches a logit.

Prefix sharing (copy-on-write): full prompt pages are content-addressed by a
chained digest (digest_i = H(digest_{i-1} || tokens of page i) — causal K/V
at position p depends only on tokens <= p, so equal chains mean bitwise-equal
pages). A later request whose chain prefix matches maps the same physical
pages (incref; never written — decode writes start past the prompt). A
partial tail page is FORKED: the registry keeps (parent digest, token tuple)
per registered page, a prefix match copies the page device-side, and the
request's first divergent token lands in its private copy. Refcounts hitting
zero move registered pages to an evictable LRU (future prefix hits revive
them) and return unregistered ones to the free list.
"""
from __future__ import annotations

import collections
import hashlib
from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SCRATCH_PAGE = 0
_ROOT = b"spion-kv-pool-root"
ROOT_DIGEST = _ROOT   # chain parent of a prompt's first page (engine-visible)


# ---------------------------------------------------------------------------
# traced cache view
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class PagedKVCache:
    """The decode-step view of a paged pool: k/v page arrays
    (L, num_pages, page, KV, hd) + a page table (B, num_blocks) of physical
    page ids (-1 = unmapped). Arrays are traced pytree children; the page
    size is static aux, so jit keys the trace on pool geometry exactly like
    SparseAttentionExec keys on block/halo."""

    def __init__(self, kp, vp, pt, *, page: int):
        self.kp = kp
        self.vp = vp
        self.pt = pt
        self.page = int(page)

    def tree_flatten(self):
        return (self.kp, self.vp, self.pt), (self.page,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        kp, vp, pt = children
        ex = cls.__new__(cls)
        ex.kp, ex.vp, ex.pt = kp, vp, pt
        ex.page = aux[0]
        return ex

    @property
    def num_blocks(self) -> int:
        return int(self.pt.shape[1])

    @property
    def seq_capacity(self) -> int:
        """Positions one page table row can address (num_blocks * page)."""
        return self.num_blocks * self.page

    def __repr__(self):
        return (f"PagedKVCache(page={self.page}, pool={tuple(self.kp.shape)}, "
                f"pt={tuple(self.pt.shape)})")


def write_target(pt, posb, page: int, *, ring: bool):
    """Physical page + in-page offset each batch row writes its new token to.

    pt (B, NB) page table; posb (B,) absolute positions. Append caches use
    block pos//page; sliding-window rings reuse table slot (pos//page) % NB —
    page `page` divides the ring length NB*page, so the ring storage slot
    pos % S lands in table slot (pos//page) % NB at offset pos % page, and
    rotated-out positions recycle the same physical pages in place. Unmapped
    entries (idle slots, reclaimed rows) clamp to the scratch page."""
    NB = pt.shape[1]
    lb = (posb // page) % NB if ring else jnp.clip(posb // page, 0, NB - 1)
    praw = jnp.take_along_axis(pt, lb[:, None], axis=1)[:, 0]
    return jnp.maximum(praw, SCRATCH_PAGE), posb % page


def scatter_token(kp, vp, layer, k_new, v_new, phys, off):
    """In-place (donation-friendly) write of one decoded token's K/V into
    layer `layer`'s active pages: kp/vp (L, NP, page, KV, hd), k_new/v_new
    (B, 1, KV, hd), phys/off (B,). This is the paged replacement for
    models.attention.update_cache's vector form — O(B) rows touched instead
    of the layer scan rewriting every slot's whole strip through its ys."""
    kp = kp.at[layer, phys, off].set(k_new[:, 0].astype(kp.dtype))
    vp = vp.at[layer, phys, off].set(v_new[:, 0].astype(vp.dtype))
    return kp, vp


# ---------------------------------------------------------------------------
# jitted pool maintenance (donated: updates alias in place on device)
# ---------------------------------------------------------------------------

def _copy_page_impl(kp, vp, src, dst):
    kp = kp.at[:, dst].set(kp[:, src])
    vp = vp.at[:, dst].set(vp[:, src])
    return kp, vp


_copy_page = jax.jit(_copy_page_impl, donate_argnums=(0, 1))


def _insert_blocks_impl(kp, vp, ks, vs, phys, first_block):
    """Write prefill K/V stacks (L, 1, Sp, KV, hd) into pages: page-sized
    block j of the prompt (j in [first_block, first_block + len(phys))) goes
    to physical page phys[j - first_block]. Sp must be a multiple of the
    page size (the engine buckets prompts to page multiples)."""
    L, NP, pg, KV, hd = kp.shape
    Sp = ks.shape[2]
    nb = phys.shape[0]
    kb = ks[:, 0].reshape(L, Sp // pg, pg, KV, hd)
    vb = vs[:, 0].reshape(L, Sp // pg, pg, KV, hd)
    ksel = jax.lax.dynamic_slice_in_dim(kb, first_block, nb, axis=1)
    vsel = jax.lax.dynamic_slice_in_dim(vb, first_block, nb, axis=1)
    kp = kp.at[:, phys].set(ksel.astype(kp.dtype))
    vp = vp.at[:, phys].set(vsel.astype(vp.dtype))
    return kp, vp


_insert_blocks = jax.jit(_insert_blocks_impl, donate_argnums=(0, 1))


def _insert_ring_impl(kp, vp, ks, vs, phys, plen):
    """Ring-layout insert for a prompt that wraps (plen >= len(phys)*page):
    ring table slot s holds, for each position in its page, the LATEST
    prompt position congruent to it mod the ring length — the same layout
    `write_target(ring=True)` produces at decode time."""
    L, NP, pg, KV, hd = kp.shape
    Sp = ks.shape[2]
    NB = phys.shape[0]
    S = NB * pg
    s = jnp.arange(S)
    p = s + ((plen - 1 - s) // S) * S
    pc = jnp.clip(p, 0, Sp - 1)
    knew = jnp.take(ks[:, 0], pc, axis=1).reshape(L, NB, pg, KV, hd)
    vnew = jnp.take(vs[:, 0], pc, axis=1).reshape(L, NB, pg, KV, hd)
    kp = kp.at[:, phys].set(knew.astype(kp.dtype))
    vp = vp.at[:, phys].set(vnew.astype(vp.dtype))
    return kp, vp


_insert_ring = jax.jit(_insert_ring_impl, donate_argnums=(0, 1))


# ---------------------------------------------------------------------------
# prefix hashing
# ---------------------------------------------------------------------------

def _digest(parent: bytes, toks) -> bytes:
    body = np.ascontiguousarray(np.asarray(toks, np.int32)).tobytes()
    return hashlib.blake2b(parent + body, digest_size=16).digest()


def chain_digests(prompt: np.ndarray, page: int) -> Tuple[List[bytes], bytes]:
    """(per-full-page chain digests, full-prompt digest). The chain makes a
    page digest cover every token before it, which is exactly what causal
    K/V content depends on."""
    prompt = np.asarray(prompt, np.int32)
    nfull = len(prompt) // page
    digests, parent = [], _ROOT
    for i in range(nfull):
        parent = _digest(parent, prompt[i * page:(i + 1) * page])
        digests.append(parent)
    tail = prompt[nfull * page:]
    full = _digest(parent, tail) if len(tail) else parent
    return digests, full


class PrefixMatch(NamedTuple):
    shared: List[int]            # physical page per prompt block 0..n-1
    digests: List[bytes]         # chain digest per FULL prompt page
    full_digest: bytes           # digest over the entire prompt
    tail_src: Optional[int]      # fork source for a partial tail page
    first_tok: Optional[int]     # cached first generated token (full hit)


# ---------------------------------------------------------------------------
# the pool
# ---------------------------------------------------------------------------

class PagePool:
    """Device page arrays + host allocator. Pages are refcounted; registered
    (content-addressed) pages at refcount 0 sit in an eviction LRU instead of
    the free list, so a hot system prompt survives its requests. Page 0 is
    reserved scratch and never allocated."""

    def __init__(self, *, layers: int, num_pages: int, page: int,
                 kv_heads: int, head_dim: int, dtype="bfloat16"):
        if num_pages < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is scratch)")
        if page < 1:
            raise ValueError("page size must be >= 1")
        self.layers = int(layers)
        self.num_pages = int(num_pages)
        self.page = int(page)
        self.kv_heads = int(kv_heads)
        self.head_dim = int(head_dim)
        self.dtype = jnp.dtype(dtype)
        shape = (self.layers, self.num_pages, self.page, self.kv_heads,
                 self.head_dim)
        self.kp = jnp.zeros(shape, self.dtype)
        self.vp = jnp.zeros(shape, self.dtype)

        self.rc = np.zeros(self.num_pages, np.int64)
        self.free: collections.deque = collections.deque(
            range(1, self.num_pages))
        self.lru: "collections.OrderedDict[int, None]" = collections.OrderedDict()
        self.by_hash = {}     # chain digest -> physical page (full pages)
        self.meta = {}        # page -> (digest, parent, token tuple, is_full)
        self.by_parent = {}   # parent digest -> [pages] (fork candidates)
        self.first_tok = {}   # full-prompt digest -> first generated token
        self.stats = {"lookups": 0, "hits": 0, "forks": 0, "evictions": 0,
                      "allocs": 0, "prefix_tokens_reused": 0,
                      "prefill_reused": 0}

    # -- accounting -----------------------------------------------------------

    @property
    def capacity(self) -> int:
        """Allocatable pages (everything but scratch)."""
        return self.num_pages - 1

    @property
    def nbytes(self) -> int:
        return 2 * int(np.prod(self.kp.shape)) * self.dtype.itemsize

    def available(self) -> int:
        """Pages an alloc() can produce right now: free + evictable LRU."""
        return len(self.free) + len(self.lru)

    def live_pages(self) -> int:
        return int(np.sum(self.rc > 0))

    # -- alloc / refcount -----------------------------------------------------

    def alloc(self, n: int) -> List[int]:
        """Take n pages (refcount 1 each), evicting LRU-cached registered
        pages as needed. Raises RuntimeError when the pool cannot satisfy
        the request — callers gate on available()."""
        if n > self.available():
            raise RuntimeError(
                f"page pool exhausted: want {n}, available {self.available()} "
                f"(capacity {self.capacity}, live {self.live_pages()})")
        out = []
        for _ in range(n):
            if self.free:
                pgid = self.free.popleft()
            else:
                pgid, _ = self.lru.popitem(last=False)
                self._unregister(pgid)
                self.stats["evictions"] += 1
            assert self.rc[pgid] == 0
            self.rc[pgid] = 1
            out.append(pgid)
        self.stats["allocs"] += n
        return out

    def incref(self, pgid: int):
        if self.rc[pgid] == 0:
            # revived from the LRU (registered page between users)
            self.lru.pop(pgid, None)
        self.rc[pgid] += 1

    def decref(self, pgid: int):
        assert self.rc[pgid] > 0, f"decref of dead page {pgid}"
        self.rc[pgid] -= 1
        if self.rc[pgid] == 0:
            if pgid in self.meta:
                self.lru[pgid] = None        # evictable, revivable
            else:
                self.free.append(pgid)

    # -- prefix registry ------------------------------------------------------

    def match_prefix(self, prompt: np.ndarray) -> PrefixMatch:
        """Pure query (no refcount changes): which leading full pages of
        `prompt` are already resident, the fork source for its partial tail
        (a registered page whose token tuple extends the tail), and the
        cached first token on a full-prompt hit."""
        pg = self.page
        prompt = np.asarray(prompt, np.int32)
        digests, full = chain_digests(prompt, pg)
        shared: List[int] = []
        for d in digests:
            pgid = self.by_hash.get(d)
            if pgid is None:
                break
            shared.append(pgid)
        self.stats["lookups"] += len(digests)
        self.stats["hits"] += len(shared)
        tail_src = None
        first = None
        if len(shared) == len(digests):
            parent = digests[-1] if digests else _ROOT
            tail = tuple(int(t) for t in prompt[len(digests) * pg:])
            if tail:
                for cand in self.by_parent.get(parent, []):
                    ctoks = self.meta[cand][2]
                    if len(ctoks) >= len(tail) and ctoks[:len(tail)] == tail:
                        tail_src = cand
                        break
            first = self.first_tok.get(full)
        return PrefixMatch(shared, digests, full, tail_src, first)

    def register_full(self, pgid: int, digest: bytes, parent: bytes,
                      tokens: Tuple[int, ...]):
        """Content-address a full prompt page for future sharing."""
        if digest in self.by_hash or pgid in self.meta:
            return
        self.meta[pgid] = (digest, parent, tuple(tokens), True)
        self.by_hash[digest] = pgid
        self.by_parent.setdefault(parent, []).append(pgid)

    def register_tail(self, pgid: int, parent: bytes,
                      tokens: Tuple[int, ...]):
        """Register a PARTIAL tail page as a fork source only (never mapped
        directly — positions past the prompt inside it belong to its owner's
        generation and are read-masked in any fork)."""
        if pgid in self.meta or not tokens:
            return
        digest = _digest(parent, np.asarray(tokens, np.int32))
        self.meta[pgid] = (digest, parent, tuple(tokens), False)
        self.by_parent.setdefault(parent, []).append(pgid)

    def remember_first_token(self, full_digest: bytes, tok: int):
        self.first_tok[full_digest] = int(tok)

    def _unregister(self, pgid: int):
        digest, parent, _toks, is_full = self.meta.pop(pgid)
        if is_full:
            self.by_hash.pop(digest, None)
        sibs = self.by_parent.get(parent)
        if sibs is not None:
            try:
                sibs.remove(pgid)
            except ValueError:
                pass
            if not sibs:
                del self.by_parent[parent]

    # -- device-side ops ------------------------------------------------------

    def copy_page(self, src: int, dst: int):
        """COW fork: duplicate page `src` into already-allocated `dst`."""
        self.kp, self.vp = _copy_page(self.kp, self.vp, jnp.int32(src),
                                      jnp.int32(dst))
        self.stats["forks"] += 1

    def insert_blocks(self, ks, vs, phys, first_block: int):
        self.kp, self.vp = _insert_blocks(
            self.kp, self.vp, ks, vs, jnp.asarray(phys, jnp.int32),
            jnp.int32(first_block))

    def insert_ring(self, ks, vs, phys, plen: int):
        self.kp, self.vp = _insert_ring(
            self.kp, self.vp, ks, vs, jnp.asarray(phys, jnp.int32),
            jnp.int32(plen))

    def cache(self, pt) -> PagedKVCache:
        """The traced view for one decode step over page table `pt`."""
        return PagedKVCache(self.kp, self.vp, pt, page=self.page)

    def absorb(self, cache: PagedKVCache):
        """Take back the (donated, updated) pool arrays after a step."""
        self.kp, self.vp = cache.kp, cache.vp

    def gather_slot(self, row: np.ndarray, length: int) -> tuple:
        """Host-side contiguous (L, length, KV, hd) K/V view of one page
        table row — for tests/inspection, not the serving path."""
        pg = self.page
        nb = (length + pg - 1) // pg
        phys = np.asarray(row[:nb], np.int32)
        if np.any(phys < 0):
            raise ValueError("gather_slot: unmapped page in requested range")
        k = np.asarray(self.kp[:, phys]).reshape(self.layers, nb * pg,
                                                 self.kv_heads, self.head_dim)
        v = np.asarray(self.vp[:, phys]).reshape(self.layers, nb * pg,
                                                 self.kv_heads, self.head_dim)
        return k[:, :length], v[:, :length]
