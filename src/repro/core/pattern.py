"""Sparsity-pattern generation: the paper's convolutional flood fill
(Algorithms 3 & 4) plus the SPION-C / SPION-F variants and the fixed-pattern
baselines (BigBird-style) the paper compares against.

Host-side NumPy: pattern generation runs ONCE per transition, on rank-0,
between jitted steps (paper §4.1). Two flood-fill implementations:
  - flood_fill_iterative: explicit stack (production; no recursion limits)
  - flood_fill_recursive: direct transcription of Alg. 4 (test oracle)
"""
from __future__ import annotations

import sys
from typing import Optional

import numpy as np


# ---------------------------------------------------------------------------
# Alg. 3 components
# ---------------------------------------------------------------------------

def diagonal_filter(F: int) -> np.ndarray:
    """The (F x F) diagonal convolution filter's diagonal taps (uniform)."""
    return np.full((F,), 1.0 / F, np.float64)


def diag_conv(a: np.ndarray, filt: np.ndarray) -> np.ndarray:
    """Eq. 3: conv_out(i,j) = sum_f a(i+f, j+f) * filt(f), zero padded."""
    L = a.shape[0]
    out = np.zeros_like(a, dtype=np.float64)
    F = len(filt)
    for f in range(F):
        out[: L - f, : L - f] += filt[f] * a[f:, f:]
    return out


def avg_pool(a: np.ndarray, B: int) -> np.ndarray:
    """Eq. 4: (L,L) -> (L/B, L/B) block means."""
    L = a.shape[0]
    nb = L // B
    return a[: nb * B, : nb * B].reshape(nb, B, nb, B).mean(axis=(1, 3))


def upsample(mask: np.ndarray, B: int) -> np.ndarray:
    """Nearest-neighbour upsample: each block entry -> B x B block (Alg.3 l.11)."""
    return np.repeat(np.repeat(mask, B, axis=0), B, axis=1)


# ---------------------------------------------------------------------------
# Alg. 4: flood fill
# ---------------------------------------------------------------------------

def _neighbors(r, c):
    return ((r + 1, c), (r, c + 1), (r + 1, c + 1))


def flood_fill_iterative(pool_out: np.ndarray, fl_out: np.ndarray, t: float) -> np.ndarray:
    """Alg. 3 lines 5-8 + Alg. 4, with an explicit DFS stack.

    Seeds: every top-row element (0, i) and left-column element (j, 0).
    From (r, c): among the 3 neighbours (down, right, down-right), those
    equal to the max AND unvisited AND > t are marked and explored.
    """
    n = pool_out.shape[0]
    for seed in [(0, i) for i in range(n)] + [(j, 0) for j in range(n)]:
        stack = [seed]
        while stack:
            r, c = stack.pop()
            if r + 1 >= n or c + 1 >= n:
                continue
            nb = _neighbors(r, c)
            vals = [pool_out[x] for x in nb]
            m = max(vals)
            for (x, v) in zip(nb, vals):
                if v == m and fl_out[x] == 0 and v > t:
                    fl_out[x] = 1
                    stack.append(x)
    return fl_out


def flood_fill_recursive(pool_out: np.ndarray, r: int, c: int,
                         fl_out: np.ndarray, t: float) -> np.ndarray:
    """Direct transcription of Alg. 4 (test oracle; recursion-limited)."""
    n = pool_out.shape[0]
    if r + 1 >= n or c + 1 >= n:
        return fl_out
    nb = _neighbors(r, c)
    vals = [pool_out[x] for x in nb]
    m = max(vals)
    for (x, v) in zip(nb, vals):
        if v == m and fl_out[x] == 0:
            if v > t:
                fl_out[x] = 1
                flood_fill_recursive(pool_out, x[0], x[1], fl_out, t)
    return fl_out


# ---------------------------------------------------------------------------
# generate_pattern (Alg. 3) + variants
# ---------------------------------------------------------------------------

def generate_pattern(
    a_s: Optional[np.ndarray],
    *,
    variant: str = "cf",
    conv_filter_size: int = 31,
    block_size: int = 64,
    alpha_quantile: float = 0.96,
    pooled: Optional[np.ndarray] = None,
    causal: bool = False,
) -> np.ndarray:
    """Return the block-level sparsity pattern fl_out (L/B x L/B) in {0,1}.

    Either `a_s` (the L x L head-averaged attention scores) or `pooled` (the
    already pooled conv output from the streaming capture path) is given.

    variant: "cf" conv+floodfill (SPION-CF) | "f" floodfill only (SPION-F)
             | "c" conv + top-(1-alpha)% blocks (SPION-C).
    causal: restrict the pattern to the lower block-triangle (decoder archs).
    """
    if pooled is None:
        assert a_s is not None
        a = np.asarray(a_s, np.float64)
        if variant in ("cf", "c"):
            a = diag_conv(a, diagonal_filter(conv_filter_size))
        pooled = avg_pool(a, block_size)
    else:
        pooled = np.asarray(pooled, np.float64)
        if variant == "f":
            # streamed capture applies the conv; SPION-F wants raw pooling.
            # The conv is linear and near-norm-preserving; with uniform taps
            # pooled-conv ~ pooled for F << B, so reuse (documented deviation).
            pass
    n = pooled.shape[0]
    if causal:
        pooled = np.where(np.tril(np.ones_like(pooled, bool)), pooled, -np.inf)

    if variant == "c":
        finite = pooled[np.isfinite(pooled)]
        t = np.quantile(finite, alpha_quantile)
        fl = (pooled > t).astype(np.int8)
    else:
        finite = pooled[np.isfinite(pooled)]
        t = float(np.quantile(finite, alpha_quantile))
        fl = np.zeros((n, n), np.int8)
        flood_fill_iterative(pooled, fl, t)

    # Alg. 3 lines 9-10: diagonal always on
    np.fill_diagonal(fl, 1)
    if causal:
        fl = np.tril(fl)
    return fl


def pattern_to_bcsr(fl_out: np.ndarray, block_size: int, max_k: Optional[int] = None):
    """Block mask -> padded BCSR tables (see core.sparse_attention.BCSR)."""
    from repro.core.sparse_attention import bcsr_from_blockmask
    return bcsr_from_blockmask(fl_out.astype(bool), block_size, max_k)


# ---------------------------------------------------------------------------
# Fixed-pattern baselines (paper §5 comparison models)
# ---------------------------------------------------------------------------

def bigbird_pattern(n: int, *, window: int = 3, num_global: int = 2,
                    num_random: int = 3, seed: int = 0, causal: bool = False) -> np.ndarray:
    """BigBird block pattern: sliding window + global rows/cols + random."""
    rng = np.random.default_rng(seed)
    m = np.zeros((n, n), np.int8)
    for off in range(-(window // 2), window // 2 + 1):
        idx = np.arange(max(0, -off), min(n, n - off))
        m[idx, idx + off] = 1
    m[:num_global, :] = 1
    m[:, :num_global] = 1
    for r in range(n):
        cols = rng.choice(n, size=min(num_random, n), replace=False)
        m[r, cols] = 1
    if causal:
        m = np.tril(m)
    np.fill_diagonal(m, 1)
    return m


def window_pattern(n: int, *, window: int = 3, causal: bool = False) -> np.ndarray:
    """Plain sliding-window (Sparse Transformer / Longformer core)."""
    return bigbird_pattern(n, window=window, num_global=0, num_random=0, causal=causal)


def density(fl_out: np.ndarray) -> float:
    return float(np.mean(fl_out > 0))
