"""Block-sparse (padded-BCSR) multi-head attention — the SPION sparse phase.

The sparsity pattern is a per-layer table:
    col_idx : (nrb, K) int32   active column-block ids per row-block, pad = -1
    nvalid  : (nrb,)   int32   number of valid entries per row (b_cnt / B)
K is the padded max-blocks-per-row; static => jit-able and load-balanced.

Semantics are the paper's (Alg. 5/6): S = softmax_P(QK^T/sqrt(hd)) V where the
softmax denominator counts pruned positions as exp(0 - max) each (Alg. 6
line 15: sum += exp(-max) * (L - b_cnt)). Causal archs (beyond-paper
extension) count only pruned *causal* positions.

Two executions:
  - `bcsr_attention` — pure-jnp gather path (CPU tests, GSPMD dry-run).
  - kernels/ops.py   — fused Pallas kernel (TPU target), same signature.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class BCSR(NamedTuple):
    col_idx: jnp.ndarray  # (nrb, K) int32, -1 padded
    nvalid: jnp.ndarray   # (nrb,) int32
    block: int            # B
    seq_len: int          # L


def bcsr_from_blockmask(mask: np.ndarray, block: int, max_k: int | None = None) -> BCSR:
    """Host-side: dense block mask (nrb, ncb) bool -> padded BCSR."""
    mask = np.asarray(mask, bool)
    nrb, ncb = mask.shape
    counts = mask.sum(axis=1)
    K = int(max_k if max_k is not None else max(int(counts.max()), 1))
    col = np.full((nrb, K), -1, np.int32)
    for r in range(nrb):
        idx = np.nonzero(mask[r])[0][:K]
        col[r, : len(idx)] = idx
    return BCSR(jnp.asarray(col), jnp.asarray(np.minimum(counts, K).astype(np.int32)),
                block, nrb * block)


def bcsr_transpose(col_idx, nvalid, ncb: int | None = None,
                   max_k: int | None = None):
    """Transpose a padded-BCSR table: (col_idx (nrb, K), nvalid (nrb,)) ->
    (row_idx (ncb, KT), nvalid_t (ncb,)).

    `row_idx[c]` lists, ascending, the row-blocks whose active set contains
    column-block `c`; entries past `nvalid_t[c]` are arbitrary in-range row
    ids (clamped padding, same convention the kernels use for `col_idx`).

    Pure jnp (gather/scatter/argsort) so it runs under jit on traced tables —
    the sparse-phase tables are step *inputs*, not compile-time constants
    (DESIGN.md §8). `max_k` bounds the padded width KT; it must be static.
    The default KT = nrb is the only always-safe bound: a vertical stripe
    (global-attention column) appears in every row-block.

    This is the FALLBACK path: when a host-built SparsityPlan supplies
    precomputed transposed tables (padded to the true width KT*), the fused
    VJP uses those instead and this never runs under jit.
    """
    col_idx = jnp.asarray(col_idx, jnp.int32)
    nvalid = jnp.asarray(nvalid, jnp.int32)
    nrb, K = col_idx.shape
    ncb = int(ncb) if ncb is not None else nrb
    valid = jnp.arange(K)[None, :] < nvalid[:, None]              # (nrb, K)
    # scatter into a dense block mask; invalid entries land in a spill column
    colc = jnp.where(valid, jnp.clip(col_idx, 0, ncb - 1), ncb)
    mask = jnp.zeros((nrb, ncb + 1), bool)
    mask = mask.at[jnp.arange(nrb)[:, None], colc].set(True)[:, :ncb]
    maskT = mask.T                                                # (ncb, nrb)
    KT = int(max_k) if max_k is not None else nrb
    # active rows first (ascending), inactive pushed to the back
    keys = jnp.where(maskT, jnp.arange(nrb)[None, :], nrb)
    row_idx = jnp.argsort(keys, axis=1)[:, :KT].astype(jnp.int32)
    nvalid_t = jnp.minimum(maskT.sum(axis=1), KT).astype(jnp.int32)
    return row_idx, nvalid_t


# the SparsityPlan's array payload — every site that threads tables through
# a step/scan filters on these keys (a missed key silently degrades the
# backward to the KT = nrb fallback, so keep the list in ONE place)
PLAN_TABLE_KEYS = ("col_idx", "nvalid", "row_idx", "nvalid_t")


class SparsityPlan(NamedTuple):
    """Host-built sparse-phase plan (DESIGN.md §8).

    `tables` is the step-input payload broadcast with the batch:
        col_idx  (Ly, nrb, K)   forward BCSR; entries past nvalid may be -1
                                (bcsr_from_blockmask convention — kernel
                                callers clamp, see ops._prep_tables)
        nvalid   (Ly, nrb)
        row_idx  (Ly, ncb, KT*) transposed BCSR; entries past nvalid_t are
                                clamped in-range row ids
        nvalid_t (Ly, ncb)
        block    int (static)
    `kt_star` is the TRUE max column population across all layers — the
    static padded width of the transposed tables, so the fused VJP's dK/dV
    grid is (N, ncb, KT*, G) instead of the always-safe (N, ncb, nrb, G).
    `stats` holds host-only occupancy numbers (never enters the jitted step).
    """
    tables: dict
    kt_star: int
    stats: dict


def host_transpose_tables(col_idx, nvalid, ncb: int | None = None,
                          max_kt: int | None = None):
    """Host-side (numpy) transpose of padded-BCSR tables, stacked or single.

    col_idx (Ly, nrb, K) / nvalid (Ly, nrb)  ->
        (row_idx (Ly, ncb, KT), nvalid_t (Ly, ncb), KT)
    with KT = the true max column population across layers (the tightest
    static width) unless `max_kt` pins it. Entries past `nvalid_t[l, c]` are
    clamped in-range row ids (same padding convention as `col_idx`), and the
    valid prefix lists row-blocks ascending — identical to the under-jit
    `bcsr_transpose` output on the valid region, but computed once at phase
    transition instead of inside every backward pass.
    """
    col = np.asarray(col_idx)
    nv = np.asarray(nvalid)
    squeeze = col.ndim == 2
    if squeeze:
        col, nv = col[None], nv[None]
    Ly, nrb, K = col.shape
    ncb = int(ncb) if ncb is not None else nrb
    # vectorized O(nnz log nnz) per layer — never materialises the dense
    # (nrb, ncb) block mask (nrb can reach ~8k at production seq lengths)
    counts = np.zeros((Ly, ncb), np.int64)
    entries = []
    for layer in range(Ly):
        rows, ks = np.nonzero(np.arange(K)[None, :] < nv[layer][:, None])
        cols = np.clip(col[layer, rows, ks], 0, ncb - 1).astype(np.int64)
        # dedupe (row, col) pairs — duplicate/clamped entries count once,
        # matching the dense-mask semantics of bcsr_transpose
        pairs = np.unique(rows.astype(np.int64) * ncb + cols)
        rows_u = (pairs // ncb).astype(np.int32)
        cols_u = (pairs % ncb).astype(np.int32)
        np.add.at(counts[layer], cols_u, 1)
        entries.append((rows_u, cols_u))
    KT = int(max_kt) if max_kt is not None else max(int(counts.max()), 1)
    row_idx = np.zeros((Ly, ncb, KT), np.int32)
    nvalid_t = np.minimum(counts, KT).astype(np.int32)
    for layer in range(Ly):
        rows_u, cols_u = entries[layer]
        order = np.lexsort((rows_u, cols_u))     # column-major, rows ascending
        rows_s, cols_s = rows_u[order], cols_u[order]
        starts = np.zeros(ncb + 1, np.int64)
        np.cumsum(counts[layer], out=starts[1:])
        pos = np.arange(len(rows_s)) - starts[cols_s]   # rank within column
        keep = pos < KT
        row_idx[layer, cols_s[keep], pos[keep]] = rows_s[keep]
        # clamped padding: repeat each column's last valid row id (0 if empty)
        nvt = nvalid_t[layer]
        fill = np.where(nvt > 0,
                        row_idx[layer, np.arange(ncb), np.maximum(nvt - 1, 0)],
                        0)
        tail = np.arange(KT)[None, :] >= nvt[:, None]
        row_idx[layer] = np.where(tail, fill[:, None], row_idx[layer])
    if squeeze:
        return row_idx[0], nvalid_t[0], KT
    return row_idx, nvalid_t, KT


def pattern_col_extents(col_idx, nvalid, *, ncb: int | None = None):
    """Host-side (numpy) per-layer column extents of a padded-BCSR pattern.

    col_idx (Ly, nrb, K) or (nrb, K) / nvalid (Ly, nrb) or (nrb,) ->
        (left (Ly,), right (Ly,)) int arrays, in BLOCK units:
        left[l]  = max over rows r of (r - min valid col of r), >= 0
        right[l] = max over rows r of (max valid col of r - r), >= 0

    These are computed from the RAW table entries — the tables alone decide
    which KV blocks the kernels ever touch; the causal / sliding-window tile
    masks only *remove* positions inside listed blocks, so the raw extent is
    an upper bound on every row-block's true column span regardless of the
    mask config (the property the halo-exchange scheme needs; causal
    patterns get right == 0 and sliding-window bands get left ~ window/B
    for free because the tables themselves are banded). Rows with no valid
    entries contribute 0."""
    col = np.asarray(col_idx, np.int64)
    nv = np.asarray(nvalid, np.int64)
    squeeze = col.ndim == 2
    if squeeze:
        col, nv = col[None], nv[None]
    Ly, nrb, K = col.shape
    ncb_ = int(ncb) if ncb is not None else nrb
    valid = np.arange(K)[None, None, :] < nv[:, :, None]          # (Ly,nrb,K)
    colc = np.clip(col, 0, ncb_ - 1)
    rows = np.arange(nrb)[None, :, None]
    left = np.where(valid, rows - colc, 0).max(axis=(1, 2))
    right = np.where(valid, colc - rows, 0).max(axis=(1, 2))
    left = np.maximum(left, 0).astype(np.int64)
    right = np.maximum(right, 0).astype(np.int64)
    if squeeze:
        return left[:1], right[:1]
    return left, right


def build_sparsity_plan(col_idx, nvalid, block: int, *, ncb: int | None = None,
                        max_kt: int | None = None) -> SparsityPlan:
    """Build the full SparsityPlan from (stacked or single-layer) forward
    BCSR tables. Pattern generation is a rare host-side event, so this runs
    in numpy; the products are cheap step inputs. Always returns stacked
    tables (single-layer inputs get Ly=1)."""
    col = np.asarray(col_idx, np.int32)
    nv = np.asarray(nvalid, np.int32)
    if col.ndim == 2:
        col, nv = col[None], nv[None]
    Ly, nrb, K = col.shape
    ncb_ = int(ncb) if ncb is not None else nrb
    row_idx, nvalid_t, kt = host_transpose_tables(col, nv, ncb=ncb_,
                                                  max_kt=max_kt)
    ext_l, ext_r = pattern_col_extents(col, nv, ncb=ncb_)
    stats = {
        "kt_star": int(kt),
        "nrb": int(nrb),
        "ncb": int(ncb_),
        "K": int(K),
        "per_layer_max_col_population": nvalid_t.max(axis=1).astype(int).tolist(),
        "per_layer_density": [round(float(d), 6)
                              for d in nv.sum(axis=1) / float(nrb * ncb_)],
        "dkv_grid_shrink": round(float(nrb) / float(kt), 4),
        # sequence-parallel halo bounds (DESIGN.md §10): the tables are one
        # stacked step input traced through the layer scan, so the shard-map
        # halo must cover every layer — the per-layer extents are kept for
        # diagnostics, the max is what the dispatch consumes
        "col_extent_left": ext_l.astype(int).tolist(),
        "col_extent_right": ext_r.astype(int).tolist(),
        # a list, not a tuple: plan_stats round-trips through checkpoint
        # JSON, which would silently turn a tuple into a list on resume
        "halo": [int(ext_l.max()), int(ext_r.max())],
    }
    tables = {
        "col_idx": jnp.asarray(col),
        "nvalid": jnp.asarray(nv),
        "row_idx": jnp.asarray(row_idx),
        "nvalid_t": jnp.asarray(nvalid_t),
        "block": int(block),
    }
    return SparsityPlan(tables, int(kt), stats)


def full_bcsr(seq_len: int, block: int) -> BCSR:
    """All-blocks-active BCSR (sparse path must equal dense attention)."""
    nrb = seq_len // block
    col = np.tile(np.arange(nrb, dtype=np.int32), (nrb, 1))
    return BCSR(jnp.asarray(col), jnp.full((nrb,), nrb, np.int32), block, seq_len)


def bcsr_attention(cfg, q, k, v, bcsr: BCSR, *, interpret_kernel=None,
                   row_chunk=None):
    """q (B,S,H,hd); k,v (B,S,KV,hd); returns (B,S,H,hd).

    Pure-jnp padded-BCSR attention with the paper's sparse-softmax
    zero-correction, chunked over row-blocks with per-chunk remat so the
    gathered block tensors are never all resident (the Pallas kernel is the
    TPU-native version; this path is its GSPMD-compatible stand-in).
    """
    nrb_total = q.shape[1] // bcsr.block
    rc = row_chunk or max(1, min(nrb_total, 2**21 // (bcsr.block * bcsr.block *
                                                      max(bcsr.col_idx.shape[1], 1))))
    if nrb_total and rc < nrb_total and nrb_total % rc == 0:
        nch = nrb_total // rc
        col = bcsr.col_idx.reshape(nch, rc, -1)
        nval = bcsr.nvalid.reshape(nch, rc)
        B_, _, H_, hd_ = q.shape
        qch = jnp.moveaxis(
            q.reshape(B_, nch, rc * bcsr.block, H_, hd_), 1, 0)
        roff = (jnp.arange(nch) * rc).astype(jnp.int32)

        @jax.checkpoint
        def one(args):
            qc, cc, nv, off = args
            return _bcsr_rows(cfg, qc, k, v,
                              BCSR(cc, nv, bcsr.block, bcsr.seq_len), off)

        # scan-with-unroll, not lax.map: see dense_attention (cost_analysis
        # counts a rolled body once)
        _, out = jax.lax.scan(lambda _, x: (None, one(x)), None,
                              (qch, col, nval, roff),
                              unroll=min(cfg.scan_unroll, nch))
        return jnp.moveaxis(out, 0, 1).reshape(q.shape)
    return _bcsr_rows(cfg, q, k, v, bcsr, jnp.int32(0))


def _bcsr_rows(cfg, q, k, v, bcsr: BCSR, row_offset):
    """BCSR attention for the row-blocks covered by q (absolute row-block
    index of q's first block = row_offset)."""
    B, Sq, H, hd = q.shape
    L = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    Bb = bcsr.block
    nrb = Sq // Bb          # row-blocks in THIS chunk
    K = bcsr.col_idx.shape[1]
    col = bcsr.col_idx      # (nrb, K)
    colc = jnp.maximum(col, 0)

    qb = q.reshape(B, nrb, Bb, KV, G, hd)
    kb = k.reshape(B, L // Bb, Bb, KV, hd)
    vb = v.reshape(B, L // Bb, Bb, KV, hd)
    # gather active key/value blocks per row-block: (B, nrb, K, Bb, KV, hd)
    kg = kb[:, colc]
    vg = vb[:, colc]

    # scores: (B, KV, G, nrb, Bb, K, Bb)
    s = jnp.einsum("brpkgh,brcqkh->bkgrpcq", qb, kg).astype(jnp.float32)
    s = s / np.sqrt(hd)

    # masks: padded blocks, causal / sliding-window within active blocks
    abs_rows = (row_offset + jnp.arange(nrb)) * Bb
    qpos = abs_rows[:, None, None, None] + jnp.arange(Bb)[None, :, None, None]
    kpos = (colc * Bb)[:, None, :, None] + jnp.arange(Bb)[None, None, None, :]
    ok = (col >= 0)[:, None, :, None]
    if cfg.causal:
        ok = ok & (qpos >= kpos)
    if cfg.sliding_window:
        ok = ok & (qpos - kpos < cfg.sliding_window)
    s = jnp.where(ok[None, None, None], s, -jnp.inf)

    sflat = s.reshape(B, KV, G, nrb, Bb, K * Bb)
    mx = jnp.max(sflat, axis=-1, keepdims=True)
    mx = jnp.maximum(mx, -1e30)  # rows with nothing active
    ex = jnp.where(jnp.isneginf(sflat), 0.0, jnp.exp(sflat - mx))
    denom = jnp.sum(ex, axis=-1, keepdims=True)

    # paper Alg. 6 line 15: pruned positions contribute exp(0 - max) each.
    ok_full = jnp.broadcast_to(ok, (nrb, Bb, K, Bb))
    stored = jnp.sum(ok_full[None, None, None].astype(jnp.int32), axis=(-2, -1)) \
        .reshape(1, 1, 1, nrb, Bb, 1)  # valid stored entries per row
    if cfg.causal:
        abs_pos = abs_rows[:, None] + jnp.arange(Bb)[None, :]
        row_total = (abs_pos + 1)[None, None, None, ..., None]
        if cfg.sliding_window:
            row_total = jnp.minimum(row_total, cfg.sliding_window)
    else:
        row_total = jnp.full((1, 1, 1, nrb, Bb, 1), L)
    zeros_cnt = jnp.maximum(row_total - stored, 0).astype(jnp.float32)
    denom = denom + zeros_cnt * jnp.exp(-mx)

    probs = (ex / denom).astype(q.dtype)
    probs = probs.reshape(B, KV, G, nrb, Bb, K, Bb)
    out = jnp.einsum("bkgrpcq,brcqkh->brpkgh", probs, vg)
    return out.reshape(B, Sq, H, hd)


def _decode_pattern_cols(pos, col_idx, nvalid, batch: int, block: int):
    """Per-row pattern columns for one-token decode: the query position's
    row-block selects its (K,) column blocks. Returns (posb (B,), colc (B,K)
    clipped column-block ids, valid (B,K) table-validity mask). Rows past
    the table clamp to the last row-block (serving callers size the plan to
    cover the cache)."""
    nrb, Kp = col_idx.shape
    posb = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(pos)), (batch,)) \
        .astype(jnp.int32)
    rb = jnp.clip(posb // block, 0, nrb - 1)
    cols = col_idx[rb]                                    # (B, K)
    nval = nvalid[rb]                                     # (B,)
    valid = (jnp.arange(Kp)[None, :] < nval[:, None]) & (cols >= 0)
    return posb, jnp.clip(cols, 0, None), valid


def _decode_gathered(cfg, q, kg, vg, posb, colc, valid, *, block: int,
                     ring_len=None):
    """Attend q over gathered pattern blocks kg/vg (B, K, block, KV, hd)
    with the Alg. 6 zero-corrected softmax. `colc`/`valid` are the logical
    column-block ids and validity from `_decode_pattern_cols` (possibly
    further masked by the caller — e.g. unmapped page-table entries);
    `ring_len` is the ring-buffer length for sliding-window caches (None
    for append caches). Shared by the contiguous and paged decode paths,
    which therefore agree bitwise when they gather the same blocks."""
    B, _, H, hd = q.shape
    KV = kg.shape[3]
    G = H // KV
    Kp = colc.shape[1]
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bcqkh->bkgcq", qg, kg).astype(jnp.float32) / np.sqrt(hd)
    # absolute positions the gathered slots are *supposed* to hold
    kpos = (colc * block)[:, :, None] + jnp.arange(block)[None, None, :]
    ok = valid[:, :, None] & (kpos >= 0) & (kpos <= posb[:, None, None])
    if cfg.sliding_window:
        ok = ok & (kpos > posb[:, None, None] - cfg.sliding_window)
    if ring_len is not None:
        # the ring holds only the last ring_len positions; older ones were
        # overwritten
        ok = ok & (kpos > posb[:, None, None] - ring_len)
    s = jnp.where(ok[:, None, None], s, -jnp.inf)
    sflat = s.reshape(B, KV, G, Kp * block)
    mx = jnp.maximum(jnp.max(sflat, axis=-1, keepdims=True), -1e30)
    ex = jnp.where(jnp.isneginf(sflat), 0.0, jnp.exp(sflat - mx))
    denom = jnp.sum(ex, axis=-1, keepdims=True)
    # Alg. 6 zero-correction: pruned visible positions count exp(-max) each
    stored = jnp.sum(ok, axis=(1, 2)).astype(jnp.int32)   # (B,)
    row_total = posb + 1
    if cfg.sliding_window:
        row_total = jnp.minimum(row_total, cfg.sliding_window)
    if ring_len is not None:
        # positions that rotated out of the ring are GONE, not pruned: the
        # dense ring decode renormalises over what the cache holds, and a
        # ring shorter than the window must match it, not the full-window
        # prefill it can no longer represent
        row_total = jnp.minimum(row_total, ring_len)
    zeros_cnt = jnp.maximum(row_total - stored, 0)[:, None, None, None] \
        .astype(jnp.float32)
    denom = denom + zeros_cnt * jnp.exp(-mx)
    probs = (ex / denom).astype(q.dtype).reshape(B, KV, G, Kp, block)
    out = jnp.einsum("bkgcq,bcqkh->bkgh", probs, vg)
    return out.reshape(B, 1, H, hd)


def sparse_decode_attention(cfg, q, k_cache, v_cache, pos, col_idx, nvalid,
                            *, block: int, ring: bool = False):
    """One-token sparse decode: attend over ONLY the KV-cache blocks the
    pattern lists for the query position's row-block (DESIGN.md §11).

    q (B,1,H,hd); caches (B,S,KV,hd); pos scalar or (B,) per-row absolute
    positions; col_idx (nrb, K) / nvalid (nrb,) — one layer's forward BCSR.
    The row-block `pos // block` selects at most K column blocks; those
    K*block cache slots are gathered and attended, so decode cost is
    O(K*block) instead of O(S_cache) — the inference payoff of the
    layer-wise pattern.

    Semantics match the sparse prefill exactly (paper Alg. 6 line 15):
    pruned causal positions contribute exp(0 - max) each to the softmax
    denominator, so a token decoded at position p produces the same
    distribution the sparse forward produces at row p (tested). Where the
    listed blocks cover every visible position the correction vanishes and
    sparse decode equals DENSE decode to kernel tolerances.

    ring=True for sliding-window ring-buffer caches (cache slot of absolute
    position p is p % S; S must be a multiple of `block`): listed column
    blocks wrap into storage blocks mod S/block, and positions that have
    rotated out of the ring are masked. Rows past the table (pos >= nrb *
    block — generation beyond the pattern's coverage) clamp to the last
    row-block; serving callers should size the plan to cover the cache
    (launch/serve.ServeEngine enforces it). Decode is causal by
    construction (a cache never holds the future), so the row total is
    pos + 1 (clipped by the sliding window) regardless of cfg.causal."""
    B, _, _H, hd = q.shape
    KV, S = k_cache.shape[2], k_cache.shape[1]
    nbc = S // block
    posb, colc, valid = _decode_pattern_cols(pos, col_idx, nvalid, B, block)
    if ring:
        sb = colc % nbc
    else:
        # append cache: blocks beyond the cache don't exist — mask, never alias
        valid = valid & (colc < nbc)
        sb = jnp.minimum(colc, nbc - 1)
    kb = k_cache.reshape(B, nbc, block, KV, hd)
    vb = v_cache.reshape(B, nbc, block, KV, hd)
    idx = sb[:, :, None, None, None]
    kg = jnp.take_along_axis(kb, idx, axis=1).astype(q.dtype)  # (B,K,blk,KV,hd)
    vg = jnp.take_along_axis(vb, idx, axis=1).astype(q.dtype)
    return _decode_gathered(cfg, q, kg, vg, posb, colc, valid, block=block,
                            ring_len=S if ring else None)


def paged_sparse_decode_attention(cfg, q, kp, vp, layer, pos, page_table,
                                  col_idx, nvalid, *, page: int,
                                  ring: bool = False):
    """`sparse_decode_attention` over a paged KV pool (core.kv_pool): the
    pattern's column blocks resolve through the request's page-table row
    instead of reshaping a contiguous per-slot cache — the O(K*block)
    gather becomes pure page indirection.

    q (B,1,H,hd); kp/vp (L, num_pages, page, KV, hd) with page == the BCSR
    block; `layer` the (traced) pool layer index; page_table (B, NB) of
    physical page ids, -1 = unmapped (masked — reads clamp to the scratch
    page, whose finite junk contributes exactly 0 through the softmax).
    ring=True recycles table slots mod NB exactly like the contiguous ring
    recycles storage blocks, so rotated-out positions reuse the same
    physical pages in place. Where every pattern-listed block is mapped the
    result is bitwise-identical to the contiguous path (same gathered
    values through the same `_decode_gathered` math — tested)."""
    B = q.shape[0]
    NB = page_table.shape[1]
    posb, colc, valid = _decode_pattern_cols(pos, col_idx, nvalid, B, page)
    if ring:
        sb = colc % NB
    else:
        valid = valid & (colc < NB)
        sb = jnp.minimum(colc, NB - 1)
    praw = jnp.take_along_axis(page_table, sb, axis=1)     # (B, K)
    valid = valid & (praw >= 0)
    phys = jnp.maximum(praw, 0)
    kg = kp[layer, phys].astype(q.dtype)                   # (B,K,page,KV,hd)
    vg = vp[layer, phys].astype(q.dtype)
    return _decode_gathered(cfg, q, kg, vg, posb, colc, valid, block=page,
                            ring_len=NB * page if ring else None)


def bcsr_attention_ops(cfg, bcsr: BCSR):
    """Analytic op count of the sparse path (paper §4.4 formula, per head):
    2*C*(2*hd+1) - L*(hd+1) with C = stored element count."""
    C = int(jnp.sum(bcsr.nvalid)) * bcsr.block * bcsr.block
    L = bcsr.seq_len
    hd = cfg.resolved_head_dim
    return 2 * C * (2 * hd + 1) - L * (hd + 1)
