"""SPION core: conv-flood-fill pattern generation, 3-phase controller,
block-sparse attention, and the paper's comparison variants."""
from repro.core.pattern import generate_pattern, pattern_to_bcsr  # noqa: F401
from repro.core.sparse_attention import BCSR, bcsr_attention, bcsr_from_blockmask, full_bcsr  # noqa: F401
