"""Comparison models from the paper's §5 evaluation:

  - Original dense Transformer: the default (spion disabled).
  - BigBird / sliding-window:    fixed block patterns fed through the SAME
                                 BCSR machinery (pattern.bigbird_pattern).
  - Reformer:                    LSH-bucketed chunk attention (this module).
  - SPION-C / SPION-F / SPION-CF: SpionConfig.variant.

`fixed_pattern_tables(...)` lets any arch train with a static pattern from
step 0 — that IS the BigBird/Longformer regime, so the baseline shares every
kernel/optimizer codepath with SPION (paper-faithful comparison).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pattern import bigbird_pattern, window_pattern
from repro.core.sparse_attention import bcsr_from_blockmask, build_sparsity_plan


def fixed_pattern_tables(kind: str, seq_len: int, block: int, num_layers: int,
                         *, causal: bool = False, seed: int = 0, **kw):
    """Full SparsityPlan tables for a fixed pattern applied to every layer —
    the BigBird/Longformer baselines get the same plan-built transposed
    tables (true width KT*) as SPION, so the backward comparison is fair."""
    n = seq_len // block
    if kind == "bigbird":
        mask = bigbird_pattern(n, causal=causal, seed=seed, **kw)
    elif kind == "window":
        mask = window_pattern(n, causal=causal, **kw)
    else:
        raise ValueError(kind)
    K = int(mask.sum(axis=1).max())
    t = bcsr_from_blockmask(mask, block, max_k=K)
    # every layer shares one mask: build the plan ONCE and broadcast, instead
    # of re-transposing num_layers identical tables
    plan = build_sparsity_plan(np.asarray(t.col_idx), np.asarray(t.nvalid),
                               block)
    tables = {k: jnp.broadcast_to(v[0], (num_layers,) + v.shape[1:])
              for k, v in plan.tables.items() if hasattr(v, "shape")}
    tables["block"] = block
    return tables


# ---------------------------------------------------------------------------
# Reformer-style LSH attention (baseline)
# ---------------------------------------------------------------------------

def lsh_attention(q, k, v, *, num_hashes: int = 2, bucket_size: int = 32,
                  key=None, causal: bool = False):
    """Angular-LSH chunked attention (Reformer, simplified):
    shared-QK hashing via random rotations; sort by bucket; attend within a
    chunk and its predecessor; average over hash rounds.
    q,k,v: (B,S,H,hd) -> (B,S,H,hd).
    """
    B, S, H, hd = q.shape
    key = key if key is not None else jax.random.key(0)
    n_buckets = max(2, S // bucket_size)
    n_buckets = n_buckets + (n_buckets % 2)
    outs = []
    for r in range(num_hashes):
        rk = jax.random.fold_in(key, r)
        R = jax.random.normal(rk, (hd, n_buckets // 2))
        proj = jnp.einsum("bshd,df->bshf", q, R)  # shared-QK: hash queries
        buckets = jnp.argmax(jnp.concatenate([proj, -proj], -1), -1)  # (B,S,H)
        # stable sort by bucket, keep inverse permutation
        order = jnp.argsort(buckets * S + jnp.arange(S)[None, :, None], axis=1)
        inv = jnp.argsort(order, axis=1)

        def gather(x, idx):
            return jnp.take_along_axis(x, idx[..., None], axis=1)

        qs, ks, vs = (gather(x, order) for x in (q, k, v))
        pos = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, H))
        pos_s = jnp.take_along_axis(pos, order, axis=1)
        nc = S // bucket_size
        qc = qs.reshape(B, nc, bucket_size, H, hd)
        # attend to own chunk + previous chunk (Reformer trick)
        kc = ks.reshape(B, nc, bucket_size, H, hd)
        vc = vs.reshape(B, nc, bucket_size, H, hd)
        k2 = jnp.concatenate([jnp.roll(kc, 1, axis=1), kc], axis=2)
        v2 = jnp.concatenate([jnp.roll(vc, 1, axis=1), vc], axis=2)
        pc = pos_s.reshape(B, nc, bucket_size, H)
        p2 = jnp.concatenate([jnp.roll(pc, 1, axis=1), pc], axis=2)
        s = jnp.einsum("bcqhd,bckhd->bchqk", qc, k2) / np.sqrt(hd)
        if causal:
            qpos = pc.transpose(0, 1, 3, 2)   # (B,nc,H,bucket)
            kpos = p2.transpose(0, 1, 3, 2)   # (B,nc,H,2*bucket)
            ok = qpos[..., :, None] >= kpos[..., None, :]
            s = jnp.where(ok, s, -jnp.inf)
        # exclude self-attention (reformer: token never attends to itself
        # unless no other target) — keep simple: allow self.
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bchqk,bckhd->bcqhd", p, v2).reshape(B, S, H, hd)
        outs.append(jnp.take_along_axis(o, inv[..., None], axis=1))
    return jnp.mean(jnp.stack(outs), axis=0)
