"""Heartbeat-driven fleet supervisor: auto-respawn for unattended training.

The in-loop machinery (StepSupervisor retries, the preemption handler, the
divergence sentinel's rollback) can only heal a process that is still
*running its loop*. A worker that is SIGKILLed, wedged inside a collective,
or spinning outside the step loop needs an EXTERNAL pair of eyes — this
module is that: a daemon (``python -m repro.launch.supervise``) that spawns
the per-process workers, watches their heartbeat files, and on any fault
kills the whole fleet and respawns it from the last committed checkpoint.

Failure taxonomy (DESIGN.md §13) — what the heartbeat JSON payload
{ts, step, phase, ...} lets the supervisor distinguish:

  exit(rc!=0)  the OS already told us: respawn
  exit(0)      worker reached its target: done (excluded from liveness)
  dead         heartbeat ts stale (> dead_timeout): the process is gone or
               so wedged its beat thread stopped — SIGKILLed workers land
               here (their file freezes at the last write)
  hung         ts FRESH but the step counter frozen (> hang_timeout): the
               beat thread still runs, the main thread does not — a stuck
               collective, a livelock, a chaos-injected hang. The check only
               arms after the first step is published: before that, a long
               jit compile of the first step looks identical to a hang.
  straggler    the worker self-reports `stragglers` (repeat straggler-step
               count from StragglerMonitor) past `straggler_limit` — the
               policy knob for "slow is as bad as dead" fleets (off by
               default)

Respawn is whole-fleet: jax.distributed cannot re-admit a single process,
so any fault tears down every worker (process-group SIGKILL — workers are
spawned with start_new_session=True precisely so their descendants die
with them), the heartbeat files are cleared, and a NEW generation starts on
a fresh coordinator port, resuming from the last committed checkpoint.
Capped exponential backoff between generations; a max-respawn budget turns
a crash-loop into a clean failure instead of an infinite burn.

Everything here is plain-process logic (no jax calls): the supervisor must
stay alive and responsive precisely when the jax runtime inside the workers
is the thing that is broken.
"""
from __future__ import annotations

import os
import signal
import socket
import subprocess
import time
from typing import Callable, List, Optional, Sequence

from repro.distributed.fault import Heartbeat


def free_port() -> int:
    s = socket.socket()
    s.bind(("", 0))
    port = s.getsockname()[1]
    s.close()
    return port


class StepTracker:
    """Remembers the last step value a worker published and when it last
    *changed* — the hang watchdog's notion of progress."""

    def __init__(self):
        self.step: Optional[int] = None
        self.since: Optional[float] = None

    def update(self, step: Optional[int], now: float):
        if step is None:
            return
        if self.step is None or int(step) != self.step:
            self.step = int(step)
            self.since = now


def classify(now: float, spawned_at: float, payload: Optional[dict],
             tracker: StepTracker, *, dead_timeout: float,
             hang_timeout: float,
             straggler_limit: Optional[int] = None) -> Optional[str]:
    """One worker's liveness verdict from its heartbeat payload: None
    (healthy), 'dead', 'hung', or 'straggler'. Pure — fully unit-testable
    with synthetic clocks. A missing payload counts from `spawned_at`
    (grace for a worker that has not written its first beat yet)."""
    last_ts = float(payload["ts"]) if payload and "ts" in payload else spawned_at
    if now - last_ts > dead_timeout:
        return "dead"
    if payload is not None:
        tracker.update(payload.get("step"), now)
    if (hang_timeout and tracker.step is not None
            and now - tracker.since > hang_timeout):
        return "hung"
    if (straggler_limit and payload
            and payload.get("stragglers", 0) >= straggler_limit):
        return "straggler"
    return None


class FleetSupervisor:
    """Spawn → watch → kill → respawn loop around a fixed worker command.

    `worker_cmd` is the argv to run per process; each worker gets
    SPION_COORDINATOR / SPION_NUM_PROCESSES / SPION_PROCESS_ID in its
    environment (a fresh coordinator port per generation — the old port may
    linger in TIME_WAIT after a kill). Workers inherit the supervisor's
    stdout/stderr so logs interleave into one stream a launcher can tail.
    """

    def __init__(self, worker_cmd: Sequence[str], nproc: int, ckpt_dir: str,
                 *, dead_timeout: float = 60.0, hang_timeout: float = 120.0,
                 poll_interval: float = 1.0, max_respawns: int = 5,
                 backoff_base: float = 1.0, backoff_max: float = 30.0,
                 straggler_limit: Optional[int] = None,
                 coordinator_host: str = "localhost",
                 env: Optional[dict] = None,
                 sleep_fn: Callable[[float], None] = time.sleep,
                 log: Callable[[str], None] = print):
        self.worker_cmd = list(worker_cmd)
        self.nproc = nproc
        self.ckpt_dir = ckpt_dir
        self.dead_timeout = dead_timeout
        self.hang_timeout = hang_timeout
        self.poll_interval = poll_interval
        self.max_respawns = max_respawns
        self.backoff_base = backoff_base
        self.backoff_max = backoff_max
        self.straggler_limit = straggler_limit
        self.coordinator_host = coordinator_host
        self.env = dict(os.environ) if env is None else dict(env)
        self.sleep_fn = sleep_fn
        self.log = log
        self.respawns = 0
        self.generation = 0
        self._procs: List[subprocess.Popen] = []

    # -- heartbeat plumbing -------------------------------------------------

    def _hb_path(self, i: int) -> str:
        return os.path.join(self.ckpt_dir, f"hb_{i}")

    def _clear_heartbeats(self):
        """Stale payloads from a dead generation would read as instant
        faults (old ts) or instant hangs (old step) for the new one."""
        for i in range(self.nproc):
            try:
                os.remove(self._hb_path(i))
            except OSError:
                pass

    # -- fleet lifecycle ----------------------------------------------------

    def _spawn_fleet(self):
        os.makedirs(self.ckpt_dir, exist_ok=True)
        self._clear_heartbeats()
        port = free_port()
        self._procs = []
        for i in range(self.nproc):
            env = dict(self.env)
            env["SPION_COORDINATOR"] = f"{self.coordinator_host}:{port}"
            env["SPION_NUM_PROCESSES"] = str(self.nproc)
            env["SPION_PROCESS_ID"] = str(i)
            self._procs.append(subprocess.Popen(
                self.worker_cmd, env=env, start_new_session=True))
        self.log(f"SUPERVISOR spawn gen={self.generation} nproc={self.nproc} "
                 f"port={port}")

    def _kill_fleet(self):
        """SIGKILL every worker's process GROUP: a wedged worker will not
        honour SIGTERM, and any helper processes it forked must not outlive
        it (they would hold the coordinator port / checkpoint locks)."""
        for p in self._procs:
            if p.poll() is None:
                try:
                    os.killpg(os.getpgid(p.pid), signal.SIGKILL)
                except (ProcessLookupError, PermissionError, OSError):
                    pass
        for p in self._procs:
            try:
                p.wait(timeout=10.0)
            except subprocess.TimeoutExpired:
                pass
        self._procs = []

    # -- one generation -----------------------------------------------------

    def _watch_generation(self) -> Optional[str]:
        """Block until this generation finishes cleanly (returns None) or a
        fault is detected (returns the reason string)."""
        spawned_at = time.time()
        trackers = [StepTracker() for _ in range(self.nproc)]
        while True:
            running = 0
            for i, p in enumerate(self._procs):
                rc = p.poll()
                if rc is not None:
                    if rc != 0:
                        return f"worker={i} exit={rc}"
                    continue  # exited 0: done, excluded from liveness
                running += 1
                verdict = classify(
                    time.time(), spawned_at, Heartbeat.read(self._hb_path(i)),
                    trackers[i], dead_timeout=self.dead_timeout,
                    hang_timeout=self.hang_timeout,
                    straggler_limit=self.straggler_limit)
                if verdict:
                    return f"worker={i} {verdict}"
            if running == 0:
                return None
            self.sleep_fn(self.poll_interval)

    def backoff(self, attempt: int) -> float:
        return min(self.backoff_base * (2.0 ** attempt), self.backoff_max)

    def run(self) -> int:
        """Supervise until the fleet completes (0) or the respawn budget is
        exhausted (1). Every respawn resumes from the last committed
        checkpoint — the workers' own maybe_resume() does that; the
        supervisor only guarantees they get to run."""
        try:
            while True:
                self._spawn_fleet()
                reason = self._watch_generation()
                if reason is None:
                    self.log(f"SUPERVISOR done gen={self.generation}")
                    return 0
                self.log(f"SUPERVISOR fault gen={self.generation} {reason}")
                self._kill_fleet()
                if self.respawns >= self.max_respawns:
                    self.log(f"SUPERVISOR giveup respawns={self.respawns}")
                    return 1
                delay = self.backoff(self.respawns)
                self.respawns += 1
                self.generation += 1
                self.log(f"SUPERVISOR respawn gen={self.generation} "
                         f"backoff={delay:.2f}s")
                self.sleep_fn(delay)
        finally:
            self._kill_fleet()  # never leave orphans, even on KeyboardInterrupt
