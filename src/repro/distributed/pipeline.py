"""GPipe-style pipeline parallelism over a mesh axis (default: 'pod').

Stage weights are stacked on a leading dim sharded over the axis; microbatch
activations flow stage-to-stage via collective_permute inside shard_map.
JAX autodiff through the scan yields the backward schedule automatically
(GPipe semantics: full forward wave then backward wave; 1F1B is a further
scheduling optimisation, out of scope). Used for the 88-layer
mistral-large-123b config when pipeline_stages > 1 (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(mesh: Mesh, axis: str, stage_fn, stage_params, x, n_micro: int):
    """Run `stage_fn(params_slice, act) -> act` as an S-stage pipeline.

    stage_params: pytree with leading dim S (= mesh.shape[axis]) on every leaf.
    x: (B, ...) batch, B divisible by n_micro; activation shape is preserved
    across stages. Returns (B, ...) outputs.
    """
    S = mesh.shape[axis]
    B = x.shape[0]
    mb = B // n_micro
    xs = x.reshape((n_micro, mb) + x.shape[1:])

    pspecs = jax.tree_util.tree_map(lambda _: P(axis), stage_params)

    def inner(params, xs):
        # params: leading dim 1 (this stage); xs: (n_micro, mb, ...) replicated
        idx = jax.lax.axis_index(axis)
        p_local = jax.tree_util.tree_map(lambda a: a[0], params)
        state = jnp.zeros(xs.shape[1:], xs.dtype)
        outputs = jnp.zeros_like(xs)

        def tick(carry, t):
            state, outputs = carry
            x_in = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            cur = jnp.where(idx == 0, x_in, state)
            y = stage_fn(p_local, cur)
            out_t = t - (S - 1)
            is_emit = (idx == S - 1) & (out_t >= 0)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jnp.where(is_emit, y, jax.lax.dynamic_index_in_dim(
                    outputs, jnp.clip(out_t, 0, n_micro - 1), 0, keepdims=False)),
                jnp.clip(out_t, 0, n_micro - 1), 0)
            nxt = jax.lax.ppermute(y, axis, [(i, (i + 1) % S) for i in range(S)])
            return (nxt, outputs), None

        (_, outputs), _ = jax.lax.scan(tick, (state, outputs),
                                       jnp.arange(n_micro + S - 1))
        # only the last stage holds real outputs; broadcast to all stages
        outputs = jax.lax.psum(
            jnp.where(idx == S - 1, outputs, jnp.zeros_like(outputs)), axis)
        return outputs

    fn = shard_map(inner, mesh=mesh,
                   in_specs=(pspecs, P()), out_specs=P(),
                   check_rep=False)
    out = fn(stage_params, xs)
    return out.reshape((B,) + x.shape[1:])
