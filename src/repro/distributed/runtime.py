"""Multi-process runtime: `jax.distributed` bring-up and the host<->device
plumbing that makes single-controller training real (DESIGN.md §12).

Everything else in this repo is written against the SPMD model — every
process runs the same program over a process-spanning mesh — and this module
owns the three places where that symmetry must be broken or enforced:

  - **Bring-up** (`initialize`): one call, before any other jax use, wires
    the process into the coordination service. Parameters come from explicit
    args or the `SPION_COORDINATOR` / `SPION_NUM_PROCESSES` /
    `SPION_PROCESS_ID` environment (set by the launcher); single-process
    runs skip it entirely and every helper below degrades to a no-op. On CPU
    backends the cross-process collective implementation is pinned to gloo —
    without it a multi-process CPU mesh initialises but hangs at the first
    psum.

  - **Single-controller host data** (`broadcast_arrays`, `host_allgather`,
    `assert_in_sync`): host-side work that must not run N times (flood-fill
    pattern generation, checkpoint decisions) runs on process 0 only and its
    results move to the other processes through a *device* collective — the
    same fabric the training step already trusts, no side channel. The
    payload protocol is two fixed-shape broadcasts (lengths, then one uint8
    buffer with a JSON header), so the non-coordinators need to know nothing
    about the content in advance. `assert_in_sync` is the loud-failure half:
    each process contributes a digest of what it *actually* holds and every
    process verifies all digests match, so a divergent SparsityPlan (or a
    torn checkpoint) kills the job instead of silently desynchronising the
    kernels.

  - **Synchronisation** (`barrier`, `any_flag`): a named rendezvous for the
    checkpoint commit protocol, and a cheap every-step OR-reduction that
    turns a per-process preemption signal (SIGTERM lands on one host) into a
    fleet-wide, same-step decision to save and exit.

All collectives here run on a private 1-D mesh over every global device and
are therefore ordered with respect to the training step's collectives as
long as they are issued from the main thread — never call into this module
from a background thread while steps are running (the CheckpointManager's
commit barrier is deferred to `wait()` for exactly this reason).
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# env vars the launcher sets for each worker (scripts/tests/schedulers)
ENV_COORDINATOR = "SPION_COORDINATOR"
ENV_NUM_PROCESSES = "SPION_NUM_PROCESSES"
ENV_PROCESS_ID = "SPION_PROCESS_ID"

_initialized = False


def initialize(coordinator: Optional[str] = None,
               num_processes: Optional[int] = None,
               process_id: Optional[int] = None) -> bool:
    """Join the `jax.distributed` coordination service (idempotent).

    Must run before any other jax call touches the backend. Args fall back
    to the SPION_* env vars; with neither, this is a single-process run and
    the call is a no-op returning False. Returns True when the process is
    part of a multi-process (or explicitly coordinated) job."""
    global _initialized
    if _initialized:
        return True
    coordinator = coordinator or os.environ.get(ENV_COORDINATOR)
    if num_processes is None and os.environ.get(ENV_NUM_PROCESSES):
        num_processes = int(os.environ[ENV_NUM_PROCESSES])
    if process_id is None and os.environ.get(ENV_PROCESS_ID):
        process_id = int(os.environ[ENV_PROCESS_ID])
    if coordinator is None or num_processes is None:
        return False
    try:
        # CPU cross-process collectives need gloo; the config is consulted
        # only by the CPU client, so setting it is harmless on TPU pods
        # (where the ICI collectives ignore it).
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:  # pragma: no cover - config renamed/removed upstream
        pass
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)
    _initialized = True
    return True


def is_initialized() -> bool:
    return _initialized


def process_index() -> int:
    return jax.process_index()


def process_count() -> int:
    return jax.process_count()


def is_coordinator() -> bool:
    """Single-controller gate: host-side work (flood-fill, checkpoint
    writes, logging) runs only where this is True."""
    return jax.process_index() == 0


# ---------------------------------------------------------------------------
# device-collective primitives
# ---------------------------------------------------------------------------

def _collective_mesh() -> Mesh:
    """Private 1-D mesh over every global device, for the host-data
    collectives. Rebuilt per call (cheap) so it always reflects the live
    device set — the runtime survives re-initialisation across restarts."""
    return Mesh(np.asarray(jax.devices()), ("bcast",))


def _sum0(mesh: Mesh):
    return jax.jit(lambda a: jnp.sum(a, axis=0),
                   out_shardings=NamedSharding(mesh, P()))


def _device_broadcast(x: np.ndarray) -> np.ndarray:
    """All processes receive global-device-0's copy of `x` (a device
    collective: device 0 contributes the payload, everyone else zeros, and
    a replicated sum over the device axis reconstructs it everywhere).
    Shape/dtype must already agree across processes."""
    devs = jax.devices()
    mesh = _collective_mesh()
    shards = []
    for d in jax.local_devices():
        payload = x if d == devs[0] else np.zeros_like(x)
        shards.append(jax.device_put(payload[None], d))
    garr = jax.make_array_from_single_device_arrays(
        (len(devs),) + x.shape, NamedSharding(mesh, P("bcast")), shards)
    # jnp.sum promotes small int dtypes (uint8 -> uint32); only one device
    # contributed non-zeros, so the values fit — cast back
    return np.asarray(_sum0(mesh)(garr)).astype(x.dtype)


def host_allgather(x: np.ndarray) -> np.ndarray:
    """Gather one host array per process -> (process_count, *x.shape) on
    every process. Each process's FIRST local device contributes its value
    into the process's slot; the sum over devices stacks them."""
    x = np.asarray(x)
    if jax.process_count() == 1:
        return x[None]
    nproc = jax.process_count()
    mesh = _collective_mesh()
    shards = []
    for i, d in enumerate(jax.local_devices()):
        buf = np.zeros((nproc,) + x.shape, x.dtype)
        if i == 0:
            buf[jax.process_index()] = x
        shards.append(jax.device_put(buf[None], d))
    garr = jax.make_array_from_single_device_arrays(
        (len(jax.devices()), nproc) + x.shape,
        NamedSharding(mesh, P("bcast")), shards)
    return np.asarray(_sum0(mesh)(garr)).astype(x.dtype)


def barrier(name: str = "") -> None:
    """Named cross-process rendezvous. The allgather doubles as a sanity
    check that every process is at the *same* barrier (the name digests
    must agree) — two processes meeting at different barriers is a
    programming error worth failing loudly on, not deadlocking over."""
    if jax.process_count() == 1:
        return
    tag = np.frombuffer(hashlib.sha256(name.encode()).digest()[:8],
                        np.uint8).copy()
    got = host_allgather(tag)
    if not (got == tag[None]).all():
        raise RuntimeError(
            f"barrier({name!r}): processes met at different barriers "
            f"(tag rows: {got.tolist()})")


def any_flag(flag: bool) -> bool:
    """Fleet-wide OR of a per-process bool (one tiny device collective).
    The preemption protocol: SIGTERM lands on one host and sets its local
    flag; every step all processes reduce the flag, so they all learn about
    the preemption at the same step boundary and can run the (collective)
    checkpoint save in lockstep."""
    if jax.process_count() == 1:
        return bool(flag)
    got = host_allgather(np.asarray([1 if flag else 0], np.int32))
    return bool(got.sum() > 0)


def any_flags(flags) -> list:
    """Element-wise fleet-wide OR of several per-process bools in ONE
    collective — the step loop carries two protocol flags (preempted,
    diverged) and paying one allgather per flag per step would double the
    per-step control-plane traffic for no reason. Same answer on every
    process at the same step."""
    flags = [bool(f) for f in flags]
    if jax.process_count() == 1:
        return flags
    got = host_allgather(np.asarray([1 if f else 0 for f in flags], np.int32))
    return [bool(v) for v in (got.sum(axis=0) > 0)]


def max_value(value: int) -> int:
    """Fleet-wide max of a per-process int (one collective). The rollback
    protocol uses it to agree on the divergence step: any process may have
    flagged locally, and every process must restore the same target."""
    if jax.process_count() == 1:
        return int(value)
    got = host_allgather(np.asarray([value], np.int64))
    return int(got.max())


# ---------------------------------------------------------------------------
# single-controller payloads
# ---------------------------------------------------------------------------

def payload_digest(arrays: Optional[dict], meta: Optional[dict] = None) -> str:
    """Deterministic hex digest of an {name: ndarray} payload (+ JSON-able
    meta): name/dtype/shape/bytes all participate, so a single flipped int32
    in a plan table changes the digest."""
    h = hashlib.sha256()
    for k in sorted(arrays or {}):
        a = np.ascontiguousarray(np.asarray((arrays or {})[k]))
        h.update(k.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    if meta is not None:
        h.update(json.dumps(meta, sort_keys=True).encode())
    return h.hexdigest()[:32]


def broadcast_arrays(arrays: Optional[dict], meta: Optional[dict] = None):
    """Coordinator's ({name: ndarray}, meta) -> every process, via device
    collectives. Non-coordinators may pass anything (ignored); they learn
    shapes/dtypes from the broadcast JSON header. Returns (arrays, meta)
    everywhere. Single-process: identity."""
    if jax.process_count() == 1:
        return arrays, meta
    if is_coordinator():
        arrays = {k: np.ascontiguousarray(np.asarray(v))
                  for k, v in (arrays or {}).items()}
        header = json.dumps({
            "meta": meta,
            "names": sorted(arrays),
            "specs": {k: [str(arrays[k].dtype), list(arrays[k].shape)]
                      for k in arrays},
        }).encode()
        payload = b"".join(arrays[k].tobytes() for k in sorted(arrays))
        lengths = np.asarray([len(header), len(payload)], np.int64)
        buf = np.frombuffer(header + payload, np.uint8).copy()
    else:
        lengths = np.zeros(2, np.int64)
        buf = None
    lengths = _device_broadcast(lengths)
    hlen, plen = int(lengths[0]), int(lengths[1])
    if buf is None:
        buf = np.zeros(hlen + plen, np.uint8)
    buf = _device_broadcast(buf)
    head = json.loads(bytes(buf[:hlen]))
    out, off = {}, hlen
    for k in head["names"]:
        dtype, shape = head["specs"][k]
        n = int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize
        out[k] = np.frombuffer(bytes(buf[off:off + n]),
                               dtype=dtype).reshape(shape).copy()
        off += n
    return out, head["meta"]


def assert_in_sync(tag: str, digest: str) -> None:
    """Every process contributes `digest`; all must match, else every
    process raises with the full per-process table. This is the loud
    failure mode for divergent single-controller state — a plan whose
    tables differ across processes would otherwise silently run different
    sparsity patterns through the kernels on different hosts."""
    if jax.process_count() == 1:
        return
    d = np.frombuffer(bytes.fromhex(digest.ljust(32, "0")[:32]),
                      np.uint8).copy()
    got = host_allgather(d)
    if not (got == got[0][None]).all():
        rows = {p: bytes(got[p]).hex() for p in range(got.shape[0])}
        raise RuntimeError(
            f"assert_in_sync({tag!r}): digest mismatch across processes — "
            f"{rows} (local process {jax.process_index()})")


# ---------------------------------------------------------------------------
# host <-> global-array movement
# ---------------------------------------------------------------------------

def make_global(mesh: Mesh, tree, pspecs):
    """Host pytree (full global content on every process) -> committed
    global jax.Arrays sharded per `pspecs` over `mesh`. The callback form
    slices each device's shard locally, so it works regardless of how many
    processes the mesh spans (and avoids the same-process device_put
    fast-path semantics diverging from the multi-process path)."""
    def one(x, spec):
        x = np.asarray(x)
        s = NamedSharding(mesh, spec)
        return jax.make_array_from_callback(x.shape, s, lambda idx: x[idx])
    return jax.tree_util.tree_map(
        one, tree, pspecs, is_leaf=lambda v: isinstance(v, P))


def fully_replicated_host(tree):
    """Pytree of jax.Arrays (possibly sharded across processes) -> host
    numpy, by an all-gathering identity jit with replicated out_shardings.
    A collective: every process must call it together. Host/numpy leaves
    pass through; fully-addressable arrays skip the collective."""
    def one(x):
        if not isinstance(x, jax.Array):
            return np.asarray(x)
        if x.is_fully_addressable:
            return np.asarray(jax.device_get(x))
        mesh = x.sharding.mesh
        rep = jax.jit(lambda a: a, out_shardings=NamedSharding(mesh, P()))(x)
        return np.asarray(rep)
    return jax.tree_util.tree_map(one, tree)
