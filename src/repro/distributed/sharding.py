"""Logical-axis sharding rules -> NamedSharding, plus a mesh context so model
code can emit sharding constraints without carrying a mesh argument.

Rules follow the Megatron/MaxText convention:
  - attention qkv/o projections:   shard the heads (output) dim over `model`
  - mlp in/gate:                   shard d_ff over `model`
  - mlp out:                       shard d_ff (input) over `model`
  - embeddings / lm head:          shard vocab over `model`
  - MoE expert tensors:            shard experts over `model` when E >= |model|,
                                   else shard d_ff within each expert
  - everything tiny (norms, bias): replicated
Stacked scan-over-layers params carry a leading layer dim (always replicated).
Activations: batch over ('pod','data') where present.
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar("repro_mesh", default=None)


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh]):
    tok = _MESH.set(mesh)
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _MESH.reset(tok)


def current_mesh() -> Optional[Mesh]:
    return _MESH.get()


def data_axes(mesh: Mesh):
    """All data-parallel-ish axes present in the mesh (pod composes with data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def sanitize_spec(mesh, spec: P, shape) -> P:
    """Drop (or shrink) spec entries whose mesh-axis product does not divide
    the corresponding dim — jit in/out shardings require exact divisibility.
    Axis names the mesh does not have are dropped first (the rules state
    the full logical layout; a (seq, data) mesh simply has no 'model')."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for d, e in zip(shape, entries):
        if isinstance(e, str) and e not in mesh.shape:
            e = None
        elif isinstance(e, (tuple, list)):
            e = tuple(a for a in e if a in mesh.shape) or None
        if e is None:
            out.append(None)
            continue
        if d % _axis_size(mesh, e) == 0:
            out.append(e)
            continue
        if isinstance(e, tuple):
            kept = None
            for k in range(len(e) - 1, 0, -1):
                if d % _axis_size(mesh, e[:k]) == 0:
                    kept = e[:k]
                    break
            out.append(kept)
        else:
            out.append(None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


# the full logical-axis vocabulary model code may name in a constrain()
# spec; anything else is a typo and must fail at trace time, not silently
# replicate (the known names merely drop to None on meshes without them)
_LOGICAL_AXES = frozenset({"pod", "data", "model", "seq"})


def constrain(x, *spec):
    """with_sharding_constraint if a mesh is active, else identity.

    `spec` entries: axis-name str, tuple of axis names, or None. The sentinel
    string "batch" expands to the mesh's data axes; KNOWN axis names the
    mesh does not have resolve to None (model code states the FULL logical
    layout — e.g. "model" on heads — and smaller meshes like a (seq, data)
    pair just ignore the absent axes), while names outside the logical
    vocabulary raise. wsc tolerates uneven dims (GSPMD pads), so no
    divisibility sanitisation here — only jit-boundary shardings need
    sanitize_spec."""
    mesh = current_mesh()
    if mesh is None:
        return x

    def one(a):
        if a not in _LOGICAL_AXES:
            raise ValueError(
                f"constrain: unknown logical axis {a!r} (valid: "
                f"{sorted(_LOGICAL_AXES)} or the 'batch' sentinel)")
        return a if a in mesh.axis_names else None

    resolved = []
    for s in spec:
        if s == "batch":
            resolved.append(data_axes(mesh))
        elif isinstance(s, str):
            resolved.append(one(s))
        elif isinstance(s, (tuple, list)):
            kept = tuple(a for a in s if one(a) is not None)
            resolved.append(kept if kept else None)
        else:
            resolved.append(s)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*resolved)))


# ---------------------------------------------------------------------------
# Fused sparse-attention kernel sharding (shard_map axis choice)
# ---------------------------------------------------------------------------

def kernel_shard_axes(mesh: Mesh, batch: int, kv_heads: int):
    """Mesh axes for the fused kernel's shard_map: (batch_axes, kv_axis).

    The kernel's natural grid axis is B*KV; the shard boundary must fall on
    a meshable dim, so the wrapper keeps B and KV separate and shards
      - batch over the data axes ('pod','data'), greedily keeping every axis
        whose size still divides the batch exactly (shard_map admits no
        padding, unlike with_sharding_constraint);
      - KV heads over 'model' when KV % |model| == 0, else KV stays
        replicated (batch-only sharding — the clean GQA fallback).
    Returns (tuple-or-None, 'model'-or-None); both None means nothing
    shards and the caller should not use the wrapper (replicated kernel
    work on every device is never the right dispatch).
    """
    acc, chosen = 1, []
    for a in data_axes(mesh):
        if mesh.shape[a] > 1 and batch % (acc * mesh.shape[a]) == 0:
            chosen.append(a)
            acc *= mesh.shape[a]
    baxes = tuple(chosen) if chosen else None
    model = mesh.shape.get("model", 1)
    kv_ax = "model" if model > 1 and kv_heads % model == 0 else None
    return baxes, kv_ax


def kernel_seq_axis(mesh: Mesh, nrb, halo):
    """'seq'-axis decision for the shard_map'd fused kernel (DESIGN.md §10).

    `nrb` is the global row-block count (seq_len / block); `halo` the
    pattern's (left, right) column extent in block units (SparsityPlan
    stats["halo"], max over layers). Returns (axis_or_None, reason): the
    axis when Q row-blocks can shard over 'seq' with a single-neighbor
    halo exchange, else None plus an actionable reason. The fit rules:

      - nrb % n == 0 (shard_map admits no padding); W = nrb // n;
      - halo_left <= W and halo_right <= W — each halo comes from ONE
        `ppermute` step to the adjacent shard;
      - halo_left + halo_right <= (n - 1) * W — the halo-extended local
        window must not alias global column-blocks (the ring wraps), or
        the dK/dV halo reduction would double-count.

    Patterns whose extent violates these (e.g. a global-attention vertical
    stripe) make the caller fall back to batch/KV sharding — loudly, never
    by silently exchanging the full sequence.
    """
    n = mesh.shape.get("seq", 1)
    if n <= 1:
        return None, "mesh has no 'seq' axis (or |seq| == 1)"
    if halo is None:
        return None, ("no pattern halo supplied — seq sharding needs the "
                      "SparsityPlan's column-extent stats (stats['halo'], "
                      "threaded as the static spion tables key 'halo')")
    if nrb is None or nrb % n != 0:
        return None, f"nrb={nrb} row-blocks not divisible by |seq|={n}"
    W = nrb // n
    h_l, h_r = int(halo[0]), int(halo[1])
    if h_l > W or h_r > W:
        return None, (f"pattern halo ({h_l},{h_r}) blocks exceeds the shard "
                      f"width W={W} — the exchange would need more than the "
                      f"adjacent shard's edge")
    if h_l + h_r > (n - 1) * W:
        return None, (f"halo window {h_l}+{W}+{h_r} blocks exceeds the "
                      f"global {nrb} — local storage would alias "
                      f"column-blocks across the ring wrap")
    return "seq", f"W={W} halo=({h_l},{h_r})"


def kernel_pspecs_from_axes(baxes, kv_ax, seq_ax=None):
    """(qspec, kvspec, table_spec) for chosen kernel shard axes — the single
    source of the shard_map wrapper's spec layout (kernels/sharded.py uses
    this; keep it in lockstep with ops._split_heads's (B,KV,G,S,hd)).
    `seq_ax` shards q's row axis and k/v's sequence axis ('seq' mode, halo
    exchange inside the body); the tables always replicate."""
    return (P(baxes, kv_ax, None, seq_ax, None),
            P(baxes, kv_ax, seq_ax, None), P())


def kernel_pspecs(mesh: Mesh, batch: int, kv_heads: int):
    """PartitionSpecs for the shard_map'd fused kernel: q (B,KV,G,S,hd),
    k/v (B,KV,S,hd), and the BCSR/SparsityPlan tables. The tables index the
    full, unsharded sequence axis (every shard streams any KV tile its rows
    reference), so they replicate per shard — they are kilobytes."""
    return kernel_pspecs_from_axes(*kernel_shard_axes(mesh, batch, kv_heads))


# ---------------------------------------------------------------------------
# Parameter sharding rules (path-name driven)
# ---------------------------------------------------------------------------

# (regex over '/'-joined param path, spec builder given ndim). Specs are for
# the *unstacked* tensor; a leading scan-layer dim is prepended as None by
# param_shardings when the leaf has one more dim than the rule expects.
_RULES = [
    # attention projections
    (r"(wq|wk|wv|wqkv)$", lambda nd: P(None, "model")),
    (r"wo$", lambda nd: P("model", None)),
    (r"(bq|bk|bv)$", lambda nd: P("model")),
    # MoE expert weights (experts, d, ff) / (experts, ff, d) — BEFORE the
    # generic mlp rules (first match wins)
    (r"experts/(w_in|w_gate)$", lambda nd: P("model", None, None)),
    (r"experts/w_out$", lambda nd: P("model", None, None)),
    (r"router/w$", lambda nd: P(None, None)),
    # gated mlp
    (r"(w_in|w_gate)$", lambda nd: P(None, "model")),
    (r"w_out$", lambda nd: P("model", None)),
    # embeddings and head
    (r"(tok_embed|lm_head)/w$", lambda nd: P("model", None) if nd == 2 else P("model")),
    (r"pos_embed/w$", lambda nd: P(None, None)),
    # ssm (rwkv/mamba) projections: shard inner dim over model
    (r"(w_r|w_k|w_v|w_g|w_xbc|w_dt|in_proj)$", lambda nd: P(None, "model")),
    (r"(out_proj)$", lambda nd: P("model", None)),
    # patch projector (vlm stub)
    (r"patch_proj/w$", lambda nd: P(None, "model") if nd == 2 else P()),
]


# fallbacks when the primary rule does not divide (e.g. mixtral's 8 experts
# on a 16-way model axis -> TP-within-expert over d_ff instead)
_FALLBACKS = [
    (r"experts/(w_in|w_gate)$", lambda nd: P(None, None, "model")),
    (r"experts/w_out$", lambda nd: P(None, "model", None)),
]


def _pad(spec: P, ndim: int) -> P:
    extra = ndim - len(spec)
    if extra > 0:
        return P(*([None] * extra), *spec)
    if extra < 0:  # rule wider than tensor (e.g. tied 1-dim) -> replicate
        return P()
    return spec


def spec_for_path(path: str, ndim: int) -> P:
    for pat, fn in _RULES:
        if re.search(pat, path):
            return _pad(fn(ndim), ndim)
    return P()  # replicated (norm scales, small biases, decay params, ...)


def spec_candidates(path: str, ndim: int):
    """Primary spec followed by divisibility fallbacks."""
    out = [spec_for_path(path, ndim)]
    for pat, fn in _FALLBACKS:
        if re.search(pat, path):
            out.append(_pad(fn(ndim), ndim))
    return out


def best_spec(mesh, path: str, leaf) -> P:
    """First candidate whose sanitised form still carries a sharding."""
    cands = spec_candidates(path, getattr(leaf, "ndim", 0))
    best = sanitize_spec(mesh, cands[0], leaf.shape)
    for c in cands:
        s = sanitize_spec(mesh, c, leaf.shape)
        if any(e is not None for e in s):
            return s
    return best


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_pspecs(params, mesh: Optional[Mesh] = None) -> dict:
    """PartitionSpec pytree matching `params`. With `mesh`, specs are
    sanitised against leaf shapes (jit-divisibility)."""
    def one(path, leaf):
        if mesh is not None:
            return best_spec(mesh, _path_str(path), leaf)
        return spec_for_path(_path_str(path), getattr(leaf, "ndim", 0))
    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(mesh: Mesh, params) -> dict:
    """NamedSharding pytree for `params` (params may be ShapeDtypeStructs)."""
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), param_pspecs(params, mesh)
    )


def zero1_pspecs(params, mesh: Mesh) -> dict:
    """ZeRO-1 optimizer-state specs: param spec + shard the largest
    still-unsharded dim over the data axes (falls back to the param spec)."""
    daxes = data_axes(mesh)

    def one(path, leaf):
        spec = best_spec(mesh, _path_str(path), leaf)
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        # largest unsharded dim that the data axes divide exactly
        cand, size = None, 0
        for i, (e, d) in enumerate(zip(entries, leaf.shape)):
            if e is None and d > size and daxes and d % _axis_size(mesh, daxes) == 0:
                cand, size = i, d
        if cand is not None:
            entries[cand] = daxes if len(daxes) > 1 else daxes[0]
        return sanitize_spec(mesh, P(*entries), leaf.shape)

    return jax.tree_util.tree_map_with_path(one, params)
