"""Fault tolerance & straggler mitigation for 1000+ node fleets.

On a real multi-host deployment the controller process aggregates per-host
heartbeats; everything below is deterministic host-side logic and is fully
unit-tested here. train.py wires it into the step loop:

  - StepSupervisor: wraps the jitted step; on exception restores the last
    checkpoint and replays (checkpoint/restart fault tolerance).
  - StragglerMonitor: per-step wall-time EWMA + z-score flags (repeat
    offenders ride the heartbeat payload into the external supervisor's
    respawn decision; elastic restore is covered by the mesh-agnostic
    CheckpointManager).
  - DivergenceSentinel: per-step loss NaN/inf + EWMA-spike detector — the
    in-loop half of the rollback protocol (DESIGN.md §13).
  - Heartbeat: per-process liveness file with a JSON payload
    {ts, step, pid, phase, ...} so the external supervisor
    (distributed/supervisor.py) can tell "process gone" (stale ts) from
    "process alive but step frozen" (fresh ts, stale step).
"""
from __future__ import annotations

import json
import math
import os
import random
import threading
import time
from typing import Callable, Optional


class StragglerMonitor:
    """EWMA of step times; flags steps (hosts) whose time exceeds
    mean + z * std. At fleet scale the same logic runs per-host on the
    controller with heartbeat timestamps."""

    REL_STD_FLOOR = 0.05   # ignore jitter below 5% of the mean step time

    def __init__(self, alpha: float = 0.1, z: float = 3.0, warmup: int = 5):
        self.alpha = alpha
        self.z = z
        self.warmup = warmup
        self.mean = 0.0
        self._m2 = 0.0        # Welford sum during warmup
        self.var = 0.0        # EWMA variance after warmup
        self.n = 0

    def observe(self, dt: float) -> bool:
        """Returns True if `dt` is a straggler observation."""
        self.n += 1
        if self.n <= self.warmup:
            delta = dt - self.mean
            self.mean += delta / self.n
            self._m2 += delta * (dt - self.mean)
            if self.n == self.warmup:
                self.var = self._m2 / max(self.n - 1, 1)
            return False
        std = math.sqrt(max(self.var, (self.REL_STD_FLOOR * self.mean) ** 2))
        is_straggler = dt > self.mean + self.z * std
        if not is_straggler:  # don't poison stats with outliers
            self.mean = (1 - self.alpha) * self.mean + self.alpha * dt
            self.var = (1 - self.alpha) * self.var + \
                self.alpha * (dt - self.mean) ** 2
        return is_straggler


class StepSupervisor:
    """Run steps with crash-restart: on an *infrastructure* failure
    (RuntimeError/OSError — device loss, preemption, I/O; ConnectionError
    is already an OSError subclass), restore() is called and the step
    retried up to `max_retries` times, with exponential backoff + jitter
    between attempts so a fleet of supervisors recovering from the same
    shared-resource failure doesn't retry in thundering lockstep.
    Programming errors (TypeError/ValueError/trace errors) re-raise
    immediately — retrying those would silently mask real bugs."""

    RETRYABLE = (RuntimeError, OSError)

    def __init__(self, restore_fn: Callable[[], None], max_retries: int = 3,
                 on_failure: Optional[Callable[[Exception], None]] = None,
                 backoff_base: float = 0.5, backoff_max: float = 30.0,
                 jitter: float = 0.25,
                 sleep_fn: Callable[[float], None] = time.sleep,
                 rng: Optional[random.Random] = None):
        self.restore_fn = restore_fn
        self.max_retries = max_retries
        self.on_failure = on_failure
        self.backoff_base = backoff_base
        self.backoff_max = backoff_max
        self.jitter = jitter
        self.sleep_fn = sleep_fn
        self.rng = rng or random.Random()
        self.restarts = 0

    def backoff(self, attempt: int) -> float:
        """Delay before retry `attempt` (0-based): capped exponential with
        multiplicative jitter in [1, 1 + jitter)."""
        base = min(self.backoff_base * (2.0 ** attempt), self.backoff_max)
        return base * (1.0 + self.jitter * self.rng.random())

    def run(self, step_fn: Callable, *args, **kwargs):
        for attempt in range(self.max_retries + 1):
            try:
                return step_fn(*args, **kwargs)
            except self.RETRYABLE as e:
                self.restarts += 1
                if self.on_failure:
                    self.on_failure(e)
                if attempt == self.max_retries:
                    raise
                self.sleep_fn(self.backoff(attempt))
                self.restore_fn()


class DivergenceSentinel:
    """Per-step loss health check: NaN/inf always flags; a finite loss
    flags when it spikes past mean + z * std of the loss EWMA (the same
    z-score machinery StragglerMonitor applies to step wall-times). A
    flagged step is only a *local* observation — train.py OR-reduces it
    fleet-wide (runtime.any_flags) so every process rolls back at the same
    step (DESIGN.md §13). reset() after a rollback: the restored loss
    trajectory restarts the EWMA rather than inheriting spike-adjacent
    stats."""

    def __init__(self, z: float = 8.0, warmup: int = 10, alpha: float = 0.05,
                 spike: bool = True):
        self.z = z
        self.warmup = warmup
        self.alpha = alpha
        self.spike = spike
        self.reset()

    def reset(self):
        self._mon = StragglerMonitor(alpha=self.alpha, z=self.z,
                                     warmup=self.warmup)

    def observe(self, loss: float) -> bool:
        """True if `loss` is divergent (non-finite, or an upward spike)."""
        if not math.isfinite(loss):
            return True
        if not self.spike:
            return False
        return self._mon.observe(loss)


class Heartbeat:
    """Host liveness file heartbeat. Each write is one JSON object
    ``{"ts": ..., "pid": ..., "step": ..., "phase": ..., ...}`` committed
    atomically (tmp + rename), so the external supervisor scanning the
    files can distinguish "process gone" (stale ts) from "process alive but
    step frozen" (fresh ts, stale step). `start_thread()` keeps ts fresh
    from a daemon thread even while the main thread is stuck inside a step
    (hung collective, compile) — exactly the case the step-progress check
    exists for; the thread only touches the local filesystem, never a
    collective, so it is safe off the main thread."""

    def __init__(self, path: str, interval: float = 10.0):
        self.path = path
        self.interval = interval
        self.last = 0.0
        self._status: dict = {}
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def beat(self, now: Optional[float] = None, step: Optional[int] = None,
             phase: Optional[str] = None, extra: Optional[dict] = None):
        """Update the payload fields and (at most every `interval`) write
        the file. `now or time.time()` would treat an explicit now=0.0
        (epoch, or a test's monotonic-from-zero clock) as "not provided"."""
        if now is None:
            now = time.time()
        with self._lock:
            if step is not None:
                self._status["step"] = int(step)
            if phase is not None:
                self._status["phase"] = str(phase)
            if extra:
                self._status.update(extra)
            if now - self.last >= self.interval:
                self._write(now)

    def pulse(self, now: Optional[float] = None):
        """Unconditional write with the latest status (the thread's beat)."""
        with self._lock:
            self._write(time.time() if now is None else now)

    def _write(self, now: float):
        # lock held by caller; atomic replace so the supervisor never reads
        # a torn payload
        payload = {"ts": now, "pid": os.getpid(), **self._status}
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, self.path)
        self.last = now

    def start_thread(self):
        """Refresh ts from a daemon thread every `interval` seconds (min
        0.05 so interval=0 test heartbeats don't spin)."""
        if self._thread is not None:
            return
        self._stop.clear()

        def _loop():
            period = max(self.interval, 0.05)
            while not self._stop.wait(period):
                self.pulse()

        self._thread = threading.Thread(target=_loop, daemon=True)
        self._thread.start()

    def stop_thread(self):
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._thread = None

    @staticmethod
    def read(path: str) -> Optional[dict]:
        """Parse one heartbeat file -> payload dict, or None if missing or
        unreadable. Legacy plain-timestamp files (pre-JSON format: the bare
        float `beat` used to write) come back as {"ts": <float>}."""
        try:
            with open(path) as f:
                raw = f.read().strip()
        except OSError:
            return None
        if not raw:
            return None
        try:
            obj = json.loads(raw)
        except ValueError:
            return None
        if isinstance(obj, dict):
            return obj
        if isinstance(obj, (int, float)):
            return {"ts": float(obj)}
        return None

    @staticmethod
    def dead_hosts(paths, timeout: float, now: Optional[float] = None):
        """Hosts whose last beat (JSON payload ts, or a legacy plain
        timestamp) is older than `timeout` — missing/unparseable files
        count as dead."""
        if now is None:
            now = time.time()
        dead = []
        for p in paths:
            payload = Heartbeat.read(p)
            t = float(payload.get("ts", 0.0)) if payload else 0.0
            if now - t > timeout:
                dead.append(p)
        return dead
