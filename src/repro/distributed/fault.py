"""Fault tolerance & straggler mitigation for 1000+ node fleets.

On a real multi-host deployment the controller process aggregates per-host
heartbeats; everything below is deterministic host-side logic and is fully
unit-tested here. train.py wires it into the step loop:

  - StepSupervisor: wraps the jitted step; on exception restores the last
    checkpoint and replays (checkpoint/restart fault tolerance).
  - StragglerMonitor: per-step wall-time EWMA + z-score flags (on a pod this
    feeds eviction / re-shard; elastic restore is covered by the
    mesh-agnostic CheckpointManager).
"""
from __future__ import annotations

import math
import random
import time
from typing import Callable, Optional


class StragglerMonitor:
    """EWMA of step times; flags steps (hosts) whose time exceeds
    mean + z * std. At fleet scale the same logic runs per-host on the
    controller with heartbeat timestamps."""

    REL_STD_FLOOR = 0.05   # ignore jitter below 5% of the mean step time

    def __init__(self, alpha: float = 0.1, z: float = 3.0, warmup: int = 5):
        self.alpha = alpha
        self.z = z
        self.warmup = warmup
        self.mean = 0.0
        self._m2 = 0.0        # Welford sum during warmup
        self.var = 0.0        # EWMA variance after warmup
        self.n = 0

    def observe(self, dt: float) -> bool:
        """Returns True if `dt` is a straggler observation."""
        self.n += 1
        if self.n <= self.warmup:
            delta = dt - self.mean
            self.mean += delta / self.n
            self._m2 += delta * (dt - self.mean)
            if self.n == self.warmup:
                self.var = self._m2 / max(self.n - 1, 1)
            return False
        std = math.sqrt(max(self.var, (self.REL_STD_FLOOR * self.mean) ** 2))
        is_straggler = dt > self.mean + self.z * std
        if not is_straggler:  # don't poison stats with outliers
            self.mean = (1 - self.alpha) * self.mean + self.alpha * dt
            self.var = (1 - self.alpha) * self.var + \
                self.alpha * (dt - self.mean) ** 2
        return is_straggler


class StepSupervisor:
    """Run steps with crash-restart: on an *infrastructure* failure
    (RuntimeError/OSError — device loss, preemption, I/O; ConnectionError
    is already an OSError subclass), restore() is called and the step
    retried up to `max_retries` times, with exponential backoff + jitter
    between attempts so a fleet of supervisors recovering from the same
    shared-resource failure doesn't retry in thundering lockstep.
    Programming errors (TypeError/ValueError/trace errors) re-raise
    immediately — retrying those would silently mask real bugs."""

    RETRYABLE = (RuntimeError, OSError)

    def __init__(self, restore_fn: Callable[[], None], max_retries: int = 3,
                 on_failure: Optional[Callable[[Exception], None]] = None,
                 backoff_base: float = 0.5, backoff_max: float = 30.0,
                 jitter: float = 0.25,
                 sleep_fn: Callable[[float], None] = time.sleep,
                 rng: Optional[random.Random] = None):
        self.restore_fn = restore_fn
        self.max_retries = max_retries
        self.on_failure = on_failure
        self.backoff_base = backoff_base
        self.backoff_max = backoff_max
        self.jitter = jitter
        self.sleep_fn = sleep_fn
        self.rng = rng or random.Random()
        self.restarts = 0

    def backoff(self, attempt: int) -> float:
        """Delay before retry `attempt` (0-based): capped exponential with
        multiplicative jitter in [1, 1 + jitter)."""
        base = min(self.backoff_base * (2.0 ** attempt), self.backoff_max)
        return base * (1.0 + self.jitter * self.rng.random())

    def run(self, step_fn: Callable, *args, **kwargs):
        for attempt in range(self.max_retries + 1):
            try:
                return step_fn(*args, **kwargs)
            except self.RETRYABLE as e:
                self.restarts += 1
                if self.on_failure:
                    self.on_failure(e)
                if attempt == self.max_retries:
                    raise
                self.sleep_fn(self.backoff(attempt))
                self.restore_fn()


class Heartbeat:
    """Host liveness file heartbeat (controller scans mtimes; hosts silent
    for > timeout are declared dead and the job re-shards elastically)."""

    def __init__(self, path: str, interval: float = 10.0):
        self.path = path
        self.interval = interval
        self.last = 0.0

    def beat(self, now: Optional[float] = None):
        # `now or time.time()` would treat an explicit now=0.0 (epoch, or a
        # test's monotonic-from-zero clock) as "not provided"
        if now is None:
            now = time.time()
        if now - self.last >= self.interval:
            with open(self.path, "w") as f:
                f.write(str(now))
            self.last = now

    @staticmethod
    def dead_hosts(paths, timeout: float, now: Optional[float] = None):
        if now is None:
            now = time.time()
        dead = []
        for p in paths:
            try:
                with open(p) as f:
                    t = float(f.read().strip() or 0)
            except OSError:
                t = 0.0
            if now - t > timeout:
                dead.append(p)
        return dead
