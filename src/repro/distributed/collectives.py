"""Explicit-collective layer (shard_map) for the distributed-optimization
tricks GSPMD cannot express on its own:

  - compressed_grad_sync: int8-quantised DP all-reduce (4x wire traffic cut;
    cross-pod links are the scarce resource at 512+ chips).
  - hierarchical_grad_sync: reduce within pod first, then across pods —
    matches the pod/ICI vs inter-pod/DCN bandwidth hierarchy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.optim.grad import compressed_psum


def _replicated_specs(tree):
    return jax.tree_util.tree_map(lambda _: P(), tree)


def compressed_grad_sync(mesh: Mesh, grads, axes=("data",)):
    """All-reduce `grads` over `axes` with int8 compression. Grads enter
    sharded-over-axes (per-shard partial sums from per-device loss) and leave
    fully synchronised. Used by train.py when grad_compression='int8'."""
    specs = _replicated_specs(grads)

    def f(g):
        return compressed_psum(g, axes if len(axes) > 1 else axes[0])

    fn = shard_map(f, mesh=mesh, in_specs=(specs,), out_specs=specs,
                   check_rep=False)
    return fn(grads)


def hierarchical_grad_sync(mesh: Mesh, grads):
    """psum within 'data' (fast ICI), then across 'pod' (slow inter-pod),
    with compression only on the slow hop."""
    specs = _replicated_specs(grads)

    def f(g):
        g = jax.tree_util.tree_map(lambda x: jax.lax.psum(x, "data"), g)
        if "pod" in mesh.axis_names:
            g = compressed_psum(g, "pod")
        return g

    fn = shard_map(f, mesh=mesh, in_specs=(specs,), out_specs=specs,
                   check_rep=False)
    return fn(grads)
