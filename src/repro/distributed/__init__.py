from repro.distributed import runtime  # noqa: F401
from repro.distributed.sharding import (  # noqa: F401
    constrain,
    mesh_context,
    param_shardings,
    spec_for_path,
)
