from repro.distributed.sharding import (  # noqa: F401
    constrain,
    mesh_context,
    param_shardings,
    spec_for_path,
)
