"""Fault injection for the multi-process recovery tests and the
`faultrecovery` bench: deterministic process kills / hangs / loss poisoning
at a chosen step, and a flaky-step wrapper for exercising StepSupervisor's
retry/backoff path.

Everything is env-driven so a subprocess launcher can arm a specific worker
without the training script knowing anything about the experiment:

  SPION_CHAOS_KILL_STEP=11      kill when the training step counter reaches 11
  SPION_CHAOS_KILL_PROC=1       only on jax.process_index() == 1 (default: all)
  SPION_CHAOS_SIGNAL=KILL       KILL (hard death, tests the resume-from-last-
                                commit path) or TERM (delivered to self, so
                                the preemption handler runs the graceful
                                save/exit protocol)
  SPION_CHAOS_HANG_STEP=12      sleep inside the step loop at step 12 — the
                                process stays alive (heartbeat thread keeps
                                ts fresh) but its step counter freezes: the
                                supervisor's hang watchdog must catch it
  SPION_CHAOS_HANG_PROC=1       restrict the hang to one process
  SPION_CHAOS_HANG_SECONDS      sleep length (default 3600 — "forever" at
                                test scale; the supervisor SIGKILLs the
                                process group long before it wakes)
  SPION_CHAOS_NAN_STEP=13       poison the params with NaN right before the
                                step — the honest divergence model: the loss
                                goes non-finite *through the real forward*,
                                and the optimizer update poisons every
                                process via the gradient psum
  SPION_CHAOS_NAN_PROC=1        restrict the poisoning to one process
  SPION_CHAOS_ONCE_DIR=/path    cross-incarnation one-shot markers: each
                                fired injection drops a marker file there,
                                so a RESPAWNED fleet replaying through the
                                armed step does not re-trigger the fault
                                (without it, a supervisor-respawned run
                                would hang/die again at the same step,
                                forever)

`Trainer` polls `ChaosMonkey.from_env()` by default, so arming chaos is
purely a launcher concern. An unarmed monkey is inert.
"""
from __future__ import annotations

import os
import signal
import time
from typing import Optional


class ChaosMonkey:
    """Injects a deterministic fault when the step counter reaches the
    armed step: kill (SIGKILL/SIGTERM), hang (sleep inside the loop), or
    NaN loss poisoning. Each kind fires at most once per process instance;
    with `once_dir` set, at most once across process incarnations too."""

    def __init__(self, kill_step: Optional[int] = None,
                 kill_process: Optional[int] = None, sig: str = "KILL",
                 hang_step: Optional[int] = None,
                 hang_process: Optional[int] = None,
                 hang_seconds: float = 3600.0,
                 nan_step: Optional[int] = None,
                 nan_process: Optional[int] = None,
                 once_dir: Optional[str] = None):
        self.kill_step = kill_step
        self.kill_process = kill_process
        self.sig = sig.upper()
        if self.sig not in ("KILL", "TERM"):
            raise ValueError(f"SPION_CHAOS_SIGNAL must be KILL or TERM, "
                             f"got {sig!r}")
        self.hang_step = hang_step
        self.hang_process = hang_process
        self.hang_seconds = hang_seconds
        self.nan_step = nan_step
        self.nan_process = nan_process
        self.once_dir = once_dir
        self.fired = False        # kill (name kept for back-compat)
        self.hang_fired = False
        self.nan_fired = False

    @classmethod
    def from_env(cls) -> Optional["ChaosMonkey"]:
        def _int(name):
            v = os.environ.get(name)
            return None if v is None else int(v)

        kill, hang, nan = (_int("SPION_CHAOS_KILL_STEP"),
                           _int("SPION_CHAOS_HANG_STEP"),
                           _int("SPION_CHAOS_NAN_STEP"))
        if kill is None and hang is None and nan is None:
            return None
        return cls(kill_step=kill,
                   kill_process=_int("SPION_CHAOS_KILL_PROC"),
                   sig=os.environ.get("SPION_CHAOS_SIGNAL", "KILL"),
                   hang_step=hang,
                   hang_process=_int("SPION_CHAOS_HANG_PROC"),
                   hang_seconds=float(
                       os.environ.get("SPION_CHAOS_HANG_SECONDS", "3600")),
                   nan_step=nan,
                   nan_process=_int("SPION_CHAOS_NAN_PROC"),
                   once_dir=os.environ.get("SPION_CHAOS_ONCE_DIR"))

    # -- one-shot bookkeeping ------------------------------------------------

    def _marker(self, kind: str) -> Optional[str]:
        if self.once_dir is None:
            return None
        return os.path.join(self.once_dir, f"chaos_fired_{kind}")

    def _once_ok(self, kind: str) -> bool:
        m = self._marker(kind)
        return m is None or not os.path.exists(m)

    def _mark(self, kind: str) -> None:
        m = self._marker(kind)
        if m is not None:
            os.makedirs(self.once_dir, exist_ok=True)
            with open(m, "w") as f:
                f.write(str(os.getpid()))

    @staticmethod
    def _on_process(proc: Optional[int]) -> bool:
        if proc is None:
            return True
        import jax
        return jax.process_index() == proc

    # -- kill ----------------------------------------------------------------

    def armed_for(self, step: int) -> bool:
        if self.fired or self.kill_step is None or step < self.kill_step:
            return False
        if not self._once_ok("kill"):
            return False
        return self._on_process(self.kill_process)

    def maybe_kill(self, step: int) -> None:
        """Call at the top of each training-loop iteration. SIGKILL is an
        abrupt death (no cleanup, no flush — the honest preemption model);
        SIGTERM goes through the installed handler, i.e. the graceful
        save-and-exit protocol."""
        if not self.armed_for(step):
            return
        self.fired = True
        self._mark("kill")  # before the kill — there is no after
        os.kill(os.getpid(),
                signal.SIGKILL if self.sig == "KILL" else signal.SIGTERM)

    # -- hang ----------------------------------------------------------------

    def maybe_hang(self, step: int, sleep_fn=time.sleep) -> None:
        """Sleep inside the step loop: the process stays alive (and its
        heartbeat thread keeps ts fresh) but the step counter freezes — the
        failure mode only the supervisor's step-progress watchdog catches.
        The marker is written before sleeping: the supervisor SIGKILLs the
        process group, so there is no code path after the sleep."""
        if (self.hang_fired or self.hang_step is None
                or step < self.hang_step or not self._once_ok("hang")
                or not self._on_process(self.hang_process)):
            return
        self.hang_fired = True
        self._mark("hang")
        sleep_fn(self.hang_seconds)

    # -- loss poisoning ------------------------------------------------------

    def poison_due(self, step: int) -> bool:
        """True exactly once, at the armed step, on the armed process: the
        caller NaN-poisons its params so the loss diverges through the real
        forward pass and the optimizer update (gradient psum) spreads the
        poison fleet-wide — the scenario the divergence sentinel's rollback
        protocol exists for."""
        if (self.nan_fired or self.nan_step is None or step < self.nan_step
                or not self._once_ok("nan")
                or not self._on_process(self.nan_process)):
            return False
        self.nan_fired = True
        self._mark("nan")
        return True


def flaky(step_fn, fail_on_calls, exc_factory=None):
    """Wrap a step fn to raise on the given 1-based call numbers — the
    deterministic stand-in for transient infrastructure failures when
    testing StepSupervisor's retry/backoff. `exc_factory` builds the
    exception (default: RuntimeError tagged with the call number)."""
    fail_on_calls = set(fail_on_calls)
    calls = {"n": 0}

    def wrapped(*args, **kwargs):
        calls["n"] += 1
        if calls["n"] in fail_on_calls:
            raise (exc_factory(calls["n"]) if exc_factory
                   else RuntimeError(f"injected fault on call {calls['n']}"))
        return step_fn(*args, **kwargs)

    wrapped.calls = calls
    return wrapped
