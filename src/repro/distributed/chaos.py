"""Fault injection for the multi-process recovery tests and the
`faultrecovery` bench: deterministic process kills at a chosen step, and a
flaky-step wrapper for exercising StepSupervisor's retry/backoff path.

The kill is env-driven so a subprocess launcher can arm a specific worker
without the training script knowing anything about the experiment:

  SPION_CHAOS_KILL_STEP=11      kill when the training step counter reaches 11
  SPION_CHAOS_KILL_PROC=1       only on jax.process_index() == 1 (default: all)
  SPION_CHAOS_SIGNAL=KILL       KILL (hard death, tests the resume-from-last-
                                commit path) or TERM (delivered to self, so
                                the preemption handler runs the graceful
                                save/exit protocol)

`Trainer` polls `ChaosMonkey.from_env()` by default, so arming chaos is
purely a launcher concern. An unarmed monkey is inert.
"""
from __future__ import annotations

import os
import signal
from typing import Optional


class ChaosMonkey:
    """Kills this process when the step counter reaches `kill_step`."""

    def __init__(self, kill_step: Optional[int] = None,
                 kill_process: Optional[int] = None, sig: str = "KILL"):
        self.kill_step = kill_step
        self.kill_process = kill_process
        self.sig = sig.upper()
        if self.sig not in ("KILL", "TERM"):
            raise ValueError(f"SPION_CHAOS_SIGNAL must be KILL or TERM, "
                             f"got {sig!r}")
        self.fired = False

    @classmethod
    def from_env(cls) -> Optional["ChaosMonkey"]:
        step = os.environ.get("SPION_CHAOS_KILL_STEP")
        if step is None:
            return None
        proc = os.environ.get("SPION_CHAOS_KILL_PROC")
        return cls(kill_step=int(step),
                   kill_process=None if proc is None else int(proc),
                   sig=os.environ.get("SPION_CHAOS_SIGNAL", "KILL"))

    def armed_for(self, step: int) -> bool:
        if self.fired or self.kill_step is None or step < self.kill_step:
            return False
        if self.kill_process is not None:
            import jax
            if jax.process_index() != self.kill_process:
                return False
        return True

    def maybe_kill(self, step: int) -> None:
        """Call at the top of each training-loop iteration. SIGKILL is an
        abrupt death (no cleanup, no flush — the honest preemption model);
        SIGTERM goes through the installed handler, i.e. the graceful
        save-and-exit protocol."""
        if not self.armed_for(step):
            return
        self.fired = True
        os.kill(os.getpid(),
                signal.SIGKILL if self.sig == "KILL" else signal.SIGTERM)


def flaky(step_fn, fail_on_calls, exc_factory=None):
    """Wrap a step fn to raise on the given 1-based call numbers — the
    deterministic stand-in for transient infrastructure failures when
    testing StepSupervisor's retry/backoff. `exc_factory` builds the
    exception (default: RuntimeError tagged with the call number)."""
    fail_on_calls = set(fail_on_calls)
    calls = {"n": 0}

    def wrapped(*args, **kwargs):
        calls["n"] += 1
        if calls["n"] in fail_on_calls:
            raise (exc_factory(calls["n"]) if exc_factory
                   else RuntimeError(f"injected fault on call {calls['n']}"))
        return step_fn(*args, **kwargs)

    wrapped.calls = calls
    return wrapped
