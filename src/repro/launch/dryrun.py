import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with the
# production shardings and extract memory / FLOPs / collective-bytes evidence.
# The two lines above MUST precede any jax import (device count locks on init).
#
# Cost accounting: XLA's cost_analysis counts a while-loop body ONCE, so a
# rolled scan-over-layers under-reports FLOPs by ~L. We therefore compile each
# cell twice more with k1/k2 fully-unrolled layers and extrapolate linearly
# (layers are homogeneous; hybrid gets a period-aware plan). The full rolled
# config is still lowered+compiled as the pass/fail + memory_analysis proof.
# cost_analysis numbers are PER-DEVICE (the partitioned module is the
# per-device program); roofline terms divide by per-chip peaks accordingly.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, all_configs, get_config  # noqa: E402
from repro.distributed.sharding import (mesh_context, param_pspecs,  # noqa: E402
                                         sanitize_spec, zero1_pspecs)
from repro.launch import hlo  # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16,  # noqa: E402
                               make_production_mesh)
from repro.core.attention_exec import SparseAttentionExec  # noqa: E402
from repro.core.sparse_attention import PLAN_TABLE_KEYS  # noqa: E402
from repro.launch.steps import (batch_pspecs, cache_pspecs, make_prefill_step,  # noqa: E402
                                make_serve_step, make_train_step,
                                spion_dryrun_tables)
from repro.models.registry import build, cache_specs, input_specs  # noqa: E402

FSDP_PARAM_THRESHOLD = 8e9  # params above this are data-sharded too (FSDP)
FULL_UNROLL = 10**6


def _f32_masters(tree):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), tree)


def _ns(mesh, spec_tree):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), spec_tree,
                                  is_leaf=lambda x: isinstance(x, P))


def _opt_specs(params_tree):
    return {
        "mu": jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_tree),
        "nu": jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_tree),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def _spion_layers(cfg):
    """Number of per-layer patterns the tables need for this cfg."""
    if cfg.family == "hybrid":
        return max(cfg.num_layers // cfg.hybrid_attn_every, 1)
    return cfg.num_layers


def build_cell(cfg, shape, mesh, mode, n_micro=1):
    """Returns (jitted_fn, example_args(ShapeDtypeStructs)) for one cell."""
    bundle = build(cfg)
    params_bf = jax.eval_shape(lambda: bundle.init(jax.random.key(0)))
    fsdp = cfg.param_count() > FSDP_PARAM_THRESHOLD
    psp = zero1_pspecs(params_bf, mesh) if fsdp else param_pspecs(params_bf, mesh)
    psp_ns = _ns(mesh, psp)
    rep = NamedSharding(mesh, P())

    if shape.kind in ("train", "prefill"):
        specs = input_specs(cfg, shape)["batch"]
        bsp_ns = _ns(mesh, batch_pspecs(cfg, specs, mesh))
        tables = None
        if mode == "sparse":
            # attention runs over the FULL concatenated sequence (vlm: patch
            # tokens are prepended, so patch+text == shape.seq_len)
            tables = spion_dryrun_tables(cfg, shape.seq_len, _spion_layers(cfg))
        if shape.kind == "train":
            params = _f32_masters(params_bf)
            opt = _opt_specs(params)
            osp = {"mu": zero1_pspecs(params, mesh), "nu": zero1_pspecs(params, mesh),
                   "count": P()}
            osp_ns = _ns(mesh, osp)
            step_fn = make_train_step(cfg, spion=(mode == "sparse"),
                                      n_micro=n_micro)
            args = [params, opt, specs, jax.ShapeDtypeStruct((), jnp.int32)]
            in_sh = [psp_ns, osp_ns, bsp_ns, rep]
            out_sh = (psp_ns, osp_ns, {"loss": rep, "gnorm": rep, "lr": rep})
            if mode == "sparse":
                # the exec carries the STATIC block/halo as pytree aux, so
                # the cell compiles the exact production step signature
                blk, halo = tables["block"], tables.get("halo")

                def fn(p, o, b, s, col, nv, row, nvt):
                    ex = SparseAttentionExec(
                        {"col_idx": col, "nvalid": nv,
                         "row_idx": row, "nvalid_t": nvt},
                        block=blk, halo=halo, phase="train")
                    return step_fn(p, o, b, s, ex)
                args += [jax.ShapeDtypeStruct(tables[k].shape, jnp.int32)
                         for k in PLAN_TABLE_KEYS]
                in_sh += [rep, rep, rep, rep]
                jf = jax.jit(fn, in_shardings=tuple(in_sh), out_shardings=out_sh,
                             donate_argnums=(0, 1))
            else:
                jf = jax.jit(step_fn, in_shardings=tuple(in_sh), out_shardings=out_sh,
                             donate_argnums=(0, 1))
            return jf, args
        # prefill
        step_fn = make_prefill_step(cfg, spion=(mode == "sparse"))
        S_out = shape.seq_len
        logits_sh = NamedSharding(mesh, sanitize_spec(
            mesh, P(tuple(a for a in ("pod", "data") if a in mesh.axis_names),
                    None, "model"),
            (shape.global_batch, S_out, cfg.vocab_size)))
        args = [params_bf, specs]
        in_sh = [psp_ns, bsp_ns]
        if mode == "sparse":
            blk, halo = tables["block"], tables.get("halo")

            def fn(p, b, col, nv, row, nvt):
                ex = SparseAttentionExec(
                    {"col_idx": col, "nvalid": nv,
                     "row_idx": row, "nvalid_t": nvt},
                    block=blk, halo=halo, phase="prefill")
                return step_fn(p, b, ex)
            args += [jax.ShapeDtypeStruct(tables[k].shape, jnp.int32)
                     for k in PLAN_TABLE_KEYS]
            in_sh += [rep, rep, rep, rep]
            jf = jax.jit(fn, in_shardings=tuple(in_sh), out_shardings=logits_sh)
        else:
            jf = jax.jit(step_fn, in_shardings=tuple(in_sh), out_shardings=logits_sh)
        return jf, args

    # decode (serve_step): one token against a seq_len cache
    spec = input_specs(cfg, shape)
    cache, tokens, pos = spec["cache"], spec["tokens"], spec["pos"]
    csp_ns = _ns(mesh, cache_pspecs(cfg, cache, mesh, shape.global_batch))
    tok_ns = _ns(mesh, batch_pspecs(cfg, tokens, mesh)) if shape.global_batch > 1 \
        else rep
    serve = make_serve_step(cfg)
    logits_sh = NamedSharding(mesh, sanitize_spec(
        mesh, P(tuple(a for a in ("pod", "data") if a in mesh.axis_names)
                if shape.global_batch > 1 else None, "model"),
        (shape.global_batch, cfg.vocab_size)))
    jf = jax.jit(serve, in_shardings=(psp_ns, csp_ns, tok_ns, rep),
                 out_shardings=(logits_sh, csp_ns), donate_argnums=(1,))
    return jf, [params_bf, cache, tokens, pos]


# ---------------------------------------------------------------------------
# cost extraction
# ---------------------------------------------------------------------------

def compile_cell(cfg, shape, mesh, mode, n_micro=1):
    jf, args = build_cell(cfg, shape, mesh, mode, n_micro=n_micro)
    lowered = jf.lower(*args)
    return lowered.compile()


def choose_n_micro(cfg, shape, mesh):
    """Pick the gradient-accumulation factor that brings estimated activation
    residency under ~8 GiB/device (measured ~2.2 x L x B_loc x S x d x 2B)."""
    if shape.kind != "train":
        return 1
    daxes = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                         if a in mesh.axis_names]))
    B = shape.global_batch
    max_n = max(B // daxes, 1)
    b_loc = max(B / daxes, 1)
    act = 2.2 * cfg.num_layers * b_loc * shape.seq_len * cfg.d_model * 2
    n = 1
    while n < max_n and act / n > 8e9:
        n *= 2
    return n


def cost_of(compiled):
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll = hlo.collective_stats(text)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "hbm_bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll["total_bytes"]),
        "coll_by_kind": coll["by_kind"],
        "census": hlo.op_census(text),
    }


def _lincomb(costs, coeffs, clamp=False):
    """Linear combination of cost dicts (flops/hbm/coll scalar; dicts by key).
    clamp=True only for FINAL results — clamping intermediate marginals (which
    legitimately go negative from fusion-boundary noise) silently zeroes them
    and corrupts the extrapolation (bug: hid the zamba2 sparse savings)."""
    out = {"flops": 0.0, "hbm_bytes": 0.0, "coll_bytes": 0.0,
           "coll_by_kind": {}, "census": {}}
    for c, w in zip(costs, coeffs):
        out["flops"] += w * c["flops"]
        out["hbm_bytes"] += w * c["hbm_bytes"]
        out["coll_bytes"] += w * c["coll_bytes"]
        for k, v in c["coll_by_kind"].items():
            out["coll_by_kind"][k] = out["coll_by_kind"].get(k, 0.0) + w * v
        for k, v in c["census"].items():
            out["census"][k] = out["census"].get(k, 0.0) + w * v
    if clamp:
        for k in ("flops", "hbm_bytes", "coll_bytes"):
            out[k] = max(out[k], 0.0)
        out["coll_by_kind"] = {k: max(v, 0.0) for k, v in out["coll_by_kind"].items()}
    return out


def _reduced(cfg, k):
    kw = dict(num_layers=k, scan_unroll=FULL_UNROLL)
    if cfg.encoder_layers:
        kw["encoder_layers"] = k
    return cfg.replace(**kw)


def extrapolated_cost(cfg, shape, mesh, mode):
    """Per-device cost for the full config via layer extrapolation."""
    L = cfg.num_layers
    if cfg.family == "hybrid":
        e = cfg.hybrid_attn_every
        napps = L // e
        c1 = cost_of(compile_cell(_reduced(cfg, 1), shape, mesh, "dense"))
        c2 = cost_of(compile_cell(_reduced(cfg, 2), shape, mesh, "dense"))
        mm = _lincomb([c2, c1], [1, -1])                      # 1 mamba layer
        c_em1 = cost_of(compile_cell(_reduced(cfg, e - 1), shape, mesh, "dense"))
        c_e = cost_of(compile_cell(_reduced(cfg, e), shape, mesh, mode))
        attn = _lincomb([c_e, c_em1, mm], [1, -1, -1])        # 1 shared-attn app
        return _lincomb([c1, mm, attn], [1, L - 1, napps], clamp=True), {
            "plan": "hybrid", "ks": [1, 2, e - 1, e]}
    k1, k2 = (1, 2)
    c1 = cost_of(compile_cell(_reduced(cfg, k1), shape, mesh, mode))
    c2 = cost_of(compile_cell(_reduced(cfg, k2), shape, mesh, mode))
    marg = _lincomb([c2, c1], [1, -1])
    if cfg.encoder_layers:
        # enc+dec scale together: L pairs
        return _lincomb([c1, marg], [1, L - k1], clamp=True), {"plan": "encdec",
                                                                "ks": [k1, k2]}
    return _lincomb([c1, marg], [1, (L - k1)], clamp=True), {"plan": "uniform",
                                                             "ks": [k1, k2]}


def analyse_memory(compiled, chips):
    try:
        mem = compiled.memory_analysis()
        memd = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        }
        memd["total_bytes"] = (memd["argument_bytes"] + memd["output_bytes"]
                               + memd["temp_bytes"] - memd["alias_bytes"])
        # the partitioned module's buffers are per-device already
        memd["per_device_gb"] = memd["total_bytes"] / chips / 2**30
        return memd
    except Exception:
        return {}


def run_cell(arch, shape_name, multi_pod, mode, outdir, verbose=True,
             cfg_override=None, skip_costs=False, mesh_override=None):
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    reason = cfg.skip_reason(shape_name)
    cellname = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}__{mode}"
    path = os.path.join(outdir, cellname + ".json")
    if reason:
        rec = {"cell": cellname, "status": "skipped", "reason": reason}
        json.dump(rec, open(path, "w"), indent=1)
        if verbose:
            print(f"[skip] {cellname}: {reason}", flush=True)
        return rec
    if mode == "sparse" and (not cfg.spion.enabled or shape.kind == "decode"):
        rec = {"cell": cellname, "status": "skipped",
               "reason": "SPION inapplicable (attention-free arch or decode shape)"}
        json.dump(rec, open(path, "w"), indent=1)
        if verbose:
            print(f"[skip] {cellname}: sparse inapplicable", flush=True)
        return rec
    mesh = mesh_override if mesh_override is not None else \
        make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    try:
        with mesh_context(mesh):
            # 1) full config, rolled scans: the compile-proof + memory analysis
            n_micro = choose_n_micro(cfg, shape, mesh)
            # sparse cells record which kernel "auto" resolves to under THIS
            # mesh (shard_map-fused vs jnp). The dispatch sees the MICRO
            # batch (the kernel is traced inside the grad-accumulation
            # scan), so resolve with global_batch // n_micro — resolving
            # with the global batch could claim "fused" for a cell whose
            # step actually dispatched jnp.
            sparse_kernel = None
            seq_sharded = None
            if mode == "sparse":
                from repro.distributed.sharding import kernel_seq_axis
                from repro.launch.steps import spion_dryrun_halo
                from repro.models.attention import resolve_sparse_kernel
                # same pattern build_cell compiles with — the recorded
                # resolution must match the compiled step's dispatch — but
                # only the cheap extent scan, not a second full plan build
                halo = spion_dryrun_halo(cfg, shape.seq_len,
                                         _spion_layers(cfg))
                nrb = max(shape.seq_len // cfg.spion.block_size, 1)
                sparse_kernel = resolve_sparse_kernel(
                    cfg, max(shape.global_batch // n_micro, 1),
                    cfg.num_kv_heads, nrb=nrb, halo=halo)
                seq_ax, seq_reason = kernel_seq_axis(mesh, nrb, halo)
                seq_sharded = {"active": seq_ax is not None,
                               "halo": list(halo) if halo else None,
                               "detail": seq_reason}
            compiled_full = compile_cell(cfg.replace(scan_unroll=1), shape, mesh,
                                         mode, n_micro=n_micro)
            t_full = time.time() - t0
            memd = analyse_memory(compiled_full, 1)  # module is per-device
            rec = {"cell": cellname, "status": "ok", "arch": arch,
                   "shape": shape_name, "mesh": "multi" if multi_pod else "single",
                   "mode": mode, "chips": chips, "n_micro": n_micro,
                   "sparse_kernel": sparse_kernel,
                   "seq_sharded": seq_sharded,
                   "t_compile_full_s": round(t_full, 1),
                   "params": cfg.param_count(),
                   "active_params": cfg.active_param_count(),
                   "memory": memd}
            # 2) layer-extrapolated per-device costs (single-pod roofline)
            if not skip_costs:
                cost, plan = extrapolated_cost(cfg, shape, mesh, mode)
                terms = hlo.roofline_terms(
                    cost["flops"], cost["hbm_bytes"], cost["coll_bytes"], 1,
                    peak_flops=PEAK_FLOPS_BF16, hbm_bw=HBM_BW,
                    link_bw=ICI_BW_PER_LINK)
                dom = max(terms, key=terms.get)
                tokens = shape.global_batch * shape.seq_len
                nd = 6 * cfg.active_param_count() * tokens
                model_flops_per_dev = (nd if shape.kind == "train"
                                       else nd / 3.0) / chips
                if shape.kind == "decode":
                    model_flops_per_dev = 2 * cfg.active_param_count() * \
                        shape.global_batch / chips
                rec.update({
                    "per_device": cost, "extrapolation": plan,
                    "roofline": terms, "dominant": dom,
                    "model_flops_per_device": model_flops_per_dev,
                    "useful_fraction": (model_flops_per_dev / cost["flops"])
                    if cost["flops"] else None,
                })
            rec["t_total_s"] = round(time.time() - t0, 1)
            if verbose:
                mem = rec["memory"].get("per_device_gb", float("nan"))
                extra = ""
                if not skip_costs:
                    extra = (f" flops/dev={rec['per_device']['flops']:.3e}"
                             f" coll/dev={rec['per_device']['coll_bytes']:.3e}B"
                             f" dominant={rec['dominant']}"
                             f" useful={rec['useful_fraction']:.2f}"
                             if rec.get("useful_fraction") else "")
                print(f"[ok] {cellname}: mem/dev={mem:.2f}GiB{extra} "
                      f"({rec['t_total_s']}s)", flush=True)
    except Exception as e:  # noqa: BLE001
        rec = {"cell": cellname, "status": "error", "error": str(e)[-2000:],
               "traceback": traceback.format_exc()[-4000:]}
        if verbose:
            print(f"[ERR] {cellname}: {str(e)[:300]}", flush=True)
    json.dump(rec, open(path, "w"), indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--mode", choices=["dense", "sparse", "both"], default="dense")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-costs", action="store_true",
                    help="compile-proof + memory only (multi-pod cells)")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    archs = args.arch.split(",") if args.arch else \
        sorted(a for a in all_configs() if a != "spion-lra")
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    modes = {"dense": ["dense"], "sparse": ["sparse"], "both": ["dense", "sparse"]}[args.mode]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                for mode in modes:
                    results.append(run_cell(arch, shape, mp, mode, args.out,
                                            skip_costs=args.skip_costs or mp))
    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    err = sum(r["status"] == "error" for r in results)
    print(f"\n== dry-run: {ok} ok / {sk} skipped / {err} errors ==")
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
