"""Continuous-batching serving engine over a paged (or contiguous) KV cache.

Each engine tick admits waiting requests — fused prefill
(make_prefill_step(with_cache=True): one full-sequence forward whose
per-layer RoPE'd K/V are inserted straight into the request's pages/slot) —
then decodes ONE token for every active slot in a single batched decode_step
with PER-SLOT positions: requests of different lengths decode at their own
offsets, finish independently, and their slots are reclaimed and refilled
mid-decode.

Paged serving (the default for attention families; DESIGN.md §14): K/V live
in a shared core.kv_pool.PagePool of cross-layer pages and each slot owns a
page-table row. Admission is free-page admission — a request enters a slot
when its WORST-CASE page budget (ceil((prompt + max_new)/page), clipped to
the ring length for sliding-window archs) fits the pool, so a request can
never run out of pages mid-decode; otherwise it queues (FIFO — never
crashes). Reclamation decrefs its pages back to the free list (shared prefix
pages survive in an eviction LRU). Decode writes scatter into the active
page through the layer-scan carry instead of rewriting every slot's whole
strip — the PR 5 decode floor.

Prefix sharing (copy-on-write, `share_prefix`): full prompt pages are
content-addressed by chained digests; a request whose prompt prefix matches
maps the same physical pages (prefilled ONCE — the millions-of-users shared
system prompt case), a partially-matching tail page is forked device-side so
the first divergent token lands in a private copy, and a full-prompt hit
reuses the recorded first token with zero prefill compute. Sharing is off
for sliding-window rings (pages are overwritten in place) and for stepwise
families (recurrent state depends on the full prefix).

Sparse serving (DESIGN.md §11): pass the training run's SparsityPlan (or its
tables payload) as `spion=` and both phases use it — the prefill runs the
same block-sparse attention the sparse training phase runs, and decode
gathers only the cache blocks the query position's pattern row lists. With
paging the page size equals the plan block, so that gather is pure page
indirection. The plan must cover the positions the engine will ever decode
(`SparseAttentionExec.coverage >= prompt + max_new`).

Cache hygiene, by construction rather than by care:
  - prefill writes only the request's own pages/slot and the batched decode
    writes each row through its own page-table row (idle and reclaimed rows
    clamp to the scratch page), so one request can never write into
    another's cache — and shared prefix pages are never written at all
    (decode writes start past the prompt);
  - padding junk the fused prefill writes past the prompt length is dead: a
    position is only ever read after the decode loop has overwritten it,
    ring slots holding stale positions are masked by the ring position
    arithmetic, and unmapped page-table entries are position-masked.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention_exec import SparseAttentionExec
from repro.core.kv_pool import PagedKVCache, PagePool, ROOT_DIGEST
from repro.core.sparse_attention import SparsityPlan
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models.registry import build


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (P,) int32
    max_new: int = 16
    out: Optional[list] = None
    done: bool = False
    slot: Optional[int] = None
    t_submit: float = 0.0
    t_first: float = 0.0         # stamped when THIS request's first token lands
    t_done: float = 0.0


class ServeEngine:
    """Continuous batching with per-slot positions and fused prefill.

    spion: None | SparsityPlan | tables payload | SparseAttentionExec —
    enables sparse prefill AND pattern-bounded sparse decode from the same
    layer-wise plan the training run produced. Refused at construction for
    families the registry marks supports_sparse_decode=False (rwkv/ssm).
    paged: None (default: the registry's supports_paged_cache flag) | bool —
    page the KV cache through a shared core.kv_pool.PagePool. page_size
    defaults to the plan block (sparse) or min(32, max_len) (dense);
    num_pages defaults to slots * (max_len/page) + 1 scratch — the
    contiguous footprint — and is the knob that makes oversubscribed pools
    (many slots, short requests) cheap.
    share_prefix: copy-on-write prompt-prefix sharing (default: on whenever
    paged + fused-prefill + non-ring). stepwise_suffix_max: a shared-prefix
    request whose uncovered suffix is at most this many tokens prefills the
    suffix stepwise THROUGH the shared pages (prefix prefilled once) instead
    of re-running the fused prefill; default 2 pages.
    prefill_bucket: prompts pad up to a multiple of this before the fused
    prefill (bounding jit retraces to one per bucket); causality makes the
    padding free and the junk K/V it writes is never read (see module
    docstring). Sparse plans prefill at the same bucketed length — the
    stacked row tables slice to the prompt's row-blocks
    (_sparse_prefill_exec), so admission stays O(prompt), not O(coverage).
    Families without a plain KV cache (ssm) or fused prefill (hybrid/vlm)
    prefill stepwise — per-request, so mixed prompt lengths still cannot
    cross-pollute.
    """

    def __init__(self, cfg, params, *, slots=4, max_len=512, spion=None,
                 prefill_bucket=32, paged=None, page_size=None,
                 num_pages=None, share_prefix=None, stepwise_suffix_max=None):
        self.cfg = cfg
        self.bundle = build(cfg)
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.prefill_bucket = prefill_bucket

        if spion is not None and not self.bundle.supports_sparse_decode:
            raise NotImplementedError(
                f"ServeEngine(spion=...): family {cfg.family!r} (arch "
                f"{cfg.name!r}) keeps recurrent state, not an attention KV "
                f"cache — registry supports_sparse_decode is False for it; "
                f"serve it densely (spion=None)")

        self.exec: Optional[SparseAttentionExec] = None
        self._prefill_exec = None
        if spion is not None:
            if isinstance(spion, SparsityPlan):
                ex = SparseAttentionExec.from_plan(spion, phase="decode")
            else:
                ex = SparseAttentionExec.coerce(spion, phase="decode")
            self.exec = ex
            self._prefill_exec = SparseAttentionExec.coerce(ex, phase="prefill")

        self._can_fuse = (self.bundle.prefill_kv is not None and cfg.causal
                          and not cfg.num_patch_tokens)
        self._spion_step = self.bundle.supports_sparse_decode
        self.paged = (self.bundle.supports_paged_cache if paged is None
                      else bool(paged))
        if self.paged and not self.bundle.supports_paged_cache:
            raise NotImplementedError(
                f"ServeEngine(paged=True): family {cfg.family!r} keeps "
                f"recurrent state, not a KV cache — paging does not apply "
                f"(registry supports_paged_cache is False)")

        if self.paged:
            self.page = int(page_size or (self.exec.block if self.exec
                                          else min(32, max_len)))
            if self.exec is not None and self.page != self.exec.block:
                raise ValueError(
                    f"page_size ({self.page}) must equal the sparsity plan "
                    f"block ({self.exec.block}) so pattern column blocks "
                    f"and page-table coordinates coincide")
            if max_len % self.page:
                raise ValueError(f"max_len ({max_len}) must be a multiple "
                                 f"of the page size ({self.page})")
            self.nblocks = max_len // self.page
            if cfg.family == "hybrid":
                from repro.models.hybrid import n_attn_apps
                pool_layers = n_attn_apps(cfg)
            else:
                pool_layers = cfg.num_layers
            npages = int(num_pages) if num_pages else slots * self.nblocks + 1
            self.pool = PagePool(
                layers=pool_layers, num_pages=npages, page=self.page,
                kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
                dtype=cfg.cache_dtype or cfg.dtype)
            self.page_tables = np.full((slots, self.nblocks), -1, np.int32)
            self._pt_dev = jnp.asarray(self.page_tables)
            self._held = [False] * slots   # finished slots keep pages mapped
            # until the next admission needs them (post-run inspection)
            if cfg.family in ("dense", "moe", "vlm", "encoder"):
                self._extra_cache = {}     # pure-KV families: nothing else
            else:
                base = self.bundle.init_cache(slots, max_len)
                self._extra_cache = {k: v for k, v in base.items()
                                     if k not in ("k", "v")}
            default_share = self._can_fuse and not cfg.sliding_window
            self.share_prefix = (default_share if share_prefix is None
                                 else bool(share_prefix))
            if self.share_prefix and not default_share:
                raise ValueError(
                    "share_prefix=True needs a fused-prefill causal family "
                    "without a sliding-window ring (ring pages are "
                    "overwritten in place; recurrent prefill state depends "
                    "on the full prefix)")
            self.stepwise_suffix_max = (2 * self.page
                                        if stepwise_suffix_max is None
                                        else int(stepwise_suffix_max))
            self.cache = None
        else:
            self.share_prefix = False
            self.cache = self.bundle.init_cache(slots, max_len)

        # per-slot NEXT decode position. Freeness is `active[s] is None`;
        # a reclaimed slot's pos stays parked at its final value — the
        # batched decode still writes an (unread) K/V row for idle slots
        # each tick, and parking it at the one position the finished
        # request never wrote (P + max_new - 1: the last generated token is
        # never fed back) keeps the request's written cache region
        # byte-stable after completion instead of scribbling on position 0.
        # (Paged idle slots whose page rows were reclaimed write to the
        # scratch page instead.)
        self.pos = np.full((slots,), -1, np.int64)
        self.active: List[Optional[Request]] = [None] * slots
        self.waiting: Deque[Request] = collections.deque()
        self.prefill_fused = 0
        self.prefill_stepwise_tokens = 0

        self._decode = jax.jit(
            make_serve_step(cfg, spion=self._spion_step), donate_argnums=(1,))
        self._decode1 = None
        if self._can_fuse:
            self._prefill = jax.jit(
                make_prefill_step(cfg, spion=True, with_cache=True))
            if not self.paged:
                self._insert = jax.jit(self._insert_fn, donate_argnums=(0,))

    # -- request lifecycle ----------------------------------------------------

    def submit(self, req: Request):
        """Queue a request; it is admitted (prefilled) at the next engine
        tick with a free slot AND — paged — a sufficient free-page budget.
        Requests that could NEVER be admitted are rejected here instead of
        parking in the queue forever: prompt + max_new is validated against
        the cache length, the sparsity plan's coverage, and the pool's
        total page capacity."""
        req.t_submit = time.time()
        req.out = []
        P = len(req.prompt)
        if P < 1:
            raise ValueError("prompt must have at least one token (the first "
                             "generated token is the argmax at its last "
                             "position)")
        if req.max_new < 1:
            raise ValueError("max_new must be >= 1")
        if not self.cfg.sliding_window and P + req.max_new > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt ({P}) + max_new ({req.max_new}) "
                f"exceeds the cache length ({self.max_len})")
        if self.exec is not None and P + req.max_new > self.exec.coverage:
            raise ValueError(
                f"request {req.rid}: prompt ({P}) + max_new ({req.max_new}) "
                f"exceeds the sparsity plan's coverage "
                f"({self.exec.coverage} positions = nrb * block); build the "
                f"plan at the serving sequence length")
        if self.paged:
            worst = self._page_budget(P, req.max_new)
            if worst > self.pool.capacity:
                raise ValueError(
                    f"request {req.rid}: worst-case page budget {worst} "
                    f"pages (prompt {P} + max_new {req.max_new} at page "
                    f"size {self.page}) exceeds the pool capacity "
                    f"({self.pool.capacity} pages) — it could never be "
                    f"admitted; raise num_pages or lower max_new")
        self.waiting.append(req)

    def step(self):
        """One engine tick: admit waiting requests into free slots (each one
        prefilled into its slot), then decode one token for every active
        slot at its own position."""
        self._admit()
        if any(r is not None for r in self.active):
            self._decode_tick()

    def run(self, requests: List[Request]):
        """Drive `requests` (any count vs slot count) to completion."""
        for r in requests:
            self.submit(r)
        while self.waiting or any(r is not None for r in self.active):
            self.step()
        return requests

    # -- inspection -----------------------------------------------------------

    @property
    def prefix_stats(self) -> dict:
        """Pool + prefill counters (prefix-hit-rate telemetry)."""
        st = dict(self.pool.stats) if self.paged else {}
        st["prefill_fused"] = self.prefill_fused
        st["prefill_stepwise_tokens"] = self.prefill_stepwise_tokens
        lk = st.get("lookups", 0)
        st["prefix_hit_rate"] = (st.get("hits", 0) / lk) if lk else 0.0
        return st

    def slot_kv(self, s: int, length: int):
        """Host (L, length, KV, hd) K/V of slot `s`'s cache — contiguous
        slice or gathered through the slot's page-table row. Tests and
        inspection, not the serving path."""
        if not self.paged:
            return (np.asarray(self.cache["k"][:, s, :length]),
                    np.asarray(self.cache["v"][:, s, :length]))
        return self.pool.gather_slot(self.page_tables[s], length)

    # -- internals ------------------------------------------------------------

    def _page_budget(self, P: int, max_new: int) -> int:
        worst = (P + max_new + self.page - 1) // self.page
        if self.cfg.sliding_window:
            worst = min(worst, self.nblocks)
        return worst

    def _ex_args(self):
        return (self.exec,) if self._spion_step else ()

    def _decode1_step(self):
        if self._decode1 is None:
            self._decode1 = jax.jit(
                make_serve_step(self.cfg, spion=self._spion_step),
                donate_argnums=(1,))
        return self._decode1

    def _admit(self):
        for s in range(self.slots):
            if not self.waiting or self.active[s] is not None:
                continue
            r = self.waiting[0]
            if self.paged:
                self._release_done_slots()
                first = self._admit_paged(r, s)
                if first is None:
                    break   # FIFO: the head of the line waits for pages
            else:
                first = self._prefill_into(r, s)
            self.waiting.popleft()
            r.slot = s
            r.out.append(first)
            r.t_first = time.time()
            self.active[s] = r
            self.pos[s] = len(r.prompt)
            if len(r.out) >= r.max_new:
                self._finish(r, s)

    def _finish(self, r: Request, s: int):
        r.done = True
        r.t_done = time.time()
        self.active[s] = None
        # paged: the slot's pages stay mapped (self._held) until the next
        # admission wants them — mirrors the contiguous engine keeping a
        # finished slot's cache region byte-stable for inspection — and are
        # released lazily by _release_done_slots.

    def _release_done_slots(self):
        """Return every finished slot's pages to the pool (decref — shared
        prefix pages survive in the registry LRU)."""
        dirty = False
        for s in range(self.slots):
            if self.active[s] is None and self._held[s]:
                row = self.page_tables[s]
                for p in np.unique(row[row >= 0]):
                    self.pool.decref(int(p))
                row[:] = -1
                self._held[s] = False
                dirty = True
        if dirty:
            self._pt_dev = jnp.asarray(self.page_tables)

    def _decode_tick(self):
        tok = np.zeros((self.slots, 1), np.int32)
        posv = np.zeros((self.slots,), np.int32)
        for s, r in enumerate(self.active):
            posv[s] = max(self.pos[s], 0)   # idle slots park (see __init__)
            if r is not None:
                tok[s, 0] = r.out[-1]
        cache = self._engine_cache()
        logits, cache = self._decode(
            self.params, cache, jnp.asarray(tok), jnp.asarray(posv),
            *self._ex_args())
        self._absorb(cache)
        nxt = np.asarray(jnp.argmax(logits, -1))
        for s, r in enumerate(self.active):
            if r is None:
                continue
            r.out.append(int(nxt[s]))
            self.pos[s] += 1
            if len(r.out) >= r.max_new:
                self._finish(r, s)

    def _engine_cache(self):
        if not self.paged:
            return self.cache
        pkv = self.pool.cache(self._pt_dev)
        return pkv if not self._extra_cache else dict(self._extra_cache,
                                                      kv=pkv)

    def _absorb(self, cache):
        if not self.paged:
            self.cache = cache
            return
        if isinstance(cache, PagedKVCache):
            pkv = cache
        else:
            pkv = cache["kv"]
            self._extra_cache = {k: v for k, v in cache.items() if k != "kv"}
        self.pool.absorb(pkv)
        self._pt_dev = pkv.pt

    # -- paged admission (free-page admission + COW prefix sharing) -----------

    def _admit_paged(self, r: Request, s: int) -> Optional[int]:
        """Map pages for request `r` into slot `s`'s page-table row and
        prefill it; returns its first generated token, or None when the
        pool cannot cover its worst-case budget yet (the request stays
        queued). All pages are mapped up front, so decode can never run
        out mid-request."""
        P = len(r.prompt)
        pg = self.page
        total = self._page_budget(P, r.max_new)
        prompt = np.asarray(r.prompt, np.int32)
        m = self.pool.match_prefix(prompt) if self.share_prefix else None
        nfull = P // pg
        tail_len = P - nfull * pg
        nshared = len(m.shared) if m else 0
        # full-prompt hit: every page resident (tail via COW fork) AND the
        # first token recorded — zero prefill compute
        cached_first = (m is not None and nshared == nfull
                        and m.first_tok is not None
                        and (tail_len == 0 or m.tail_src is not None))
        if not cached_first and tail_len == 0 and nshared == nfull:
            # the would-be refeed case: the last prompt position lives in a
            # SHARED page we must not write — recompute that page privately
            nshared = max(nfull - 1, 0)

        need = total - nshared
        if m:
            for p in m.shared[:nshared]:
                self.pool.incref(p)
        if self.pool.available() < need:
            if m:
                for p in m.shared[:nshared]:
                    self.pool.decref(p)
            return None
        fresh = self.pool.alloc(need)
        row = self.page_tables[s]
        row[:] = -1
        if nshared:
            row[:nshared] = m.shared[:nshared]
        row[nshared:total] = fresh
        self._held[s] = True
        if cached_first and tail_len:
            self.pool.copy_page(m.tail_src, int(row[nfull]))
        self._pt_dev = jnp.asarray(self.page_tables)

        covered = P if cached_first else nshared * pg
        if cached_first:
            first = int(m.first_tok)
            self.pool.stats["prefill_reused"] += 1
            self.pool.stats["prefix_tokens_reused"] += P
        elif (self._can_fuse
              and (covered == 0 or P - covered > self.stepwise_suffix_max)):
            first = self._fused_prefill_paged(r, s, nshared)
            if m:
                self.pool.stats["prefix_tokens_reused"] += covered
        else:
            first = self._stepwise_prefill_paged(r, s, covered)
            if m:
                self.pool.stats["prefix_tokens_reused"] += covered
        if m is not None and not cached_first:
            self._register_prompt(prompt, m, row, nshared, first)
        return first

    def _register_prompt(self, prompt, m, row, nshared, first):
        pg = self.page
        nfull = len(m.digests)
        for i in range(nshared, nfull):
            parent = m.digests[i - 1] if i else ROOT_DIGEST
            self.pool.register_full(int(row[i]), m.digests[i], parent,
                                    tuple(int(t) for t in
                                          prompt[i * pg:(i + 1) * pg]))
        tail = tuple(int(t) for t in prompt[nfull * pg:])
        if tail:
            parent = m.digests[-1] if nfull else ROOT_DIGEST
            self.pool.register_tail(int(row[nfull]), parent, tail)
        self.pool.remember_first_token(m.full_digest, first)

    def _fused_prefill_paged(self, r: Request, s: int, nshared: int) -> int:
        """Fused full-sequence prefill; page-sized blocks [nshared,
        ceil(P/page)) of the resulting K/V stacks are scattered into the
        slot's freshly-allocated pages (shared prefix pages are left
        untouched). Ring prompts that wrap insert in ring layout."""
        P = len(r.prompt)
        pg = self.page
        Sp = self._prefill_len(P)
        toks = np.zeros((1, Sp), np.int32)
        toks[0, :P] = r.prompt
        pex = None if self._prefill_exec is None \
            else self._sparse_prefill_exec(Sp)
        logits, ks, vs = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)}, pex)
        row = self.page_tables[s]
        ring_len = self.nblocks * pg
        if self.cfg.sliding_window and P >= ring_len:
            self.pool.insert_ring(ks, vs, row[:self.nblocks], P)
        else:
            nb_prompt = (P + pg - 1) // pg
            if nb_prompt > nshared:
                self.pool.insert_blocks(ks, vs, row[nshared:nb_prompt],
                                        nshared)
        self.prefill_fused += 1
        return int(jnp.argmax(logits[0, P - 1]))

    def _stepwise_prefill_paged(self, r: Request, s: int, start: int) -> int:
        """Teacher-force prompt positions [start, P) one at a time through
        the slot's page-table row with a B=1 decode step — attending the
        SHARED prefix pages below `start` without recomputing them (this is
        what makes 'prefill once' literal for shared-prefix suffixes), or
        from 0 for families without fused prefill (their fresh per-request
        conv/ssm states are written into the slot afterwards)."""
        P = len(r.prompt)
        step1 = self._decode1_step()
        sub_extra = {}
        if self._extra_cache:
            sub_extra = {k: v for k, v in
                         self.bundle.init_cache(1, self.max_len).items()
                         if k not in ("k", "v")}
        ptrow = jnp.asarray(self.page_tables[s:s + 1])
        logits = None
        for t in range(start, P):
            pkv = self.pool.cache(ptrow)
            cache1 = pkv if not sub_extra else dict(sub_extra, kv=pkv)
            logits, cache1 = step1(
                self.params, cache1,
                jnp.asarray([[r.prompt[t]]], np.int32), jnp.int32(t),
                *self._ex_args())
            if sub_extra:
                pkv = cache1["kv"]
                sub_extra = {k: v for k, v in cache1.items() if k != "kv"}
            else:
                pkv = cache1
            self.pool.absorb(pkv)
            ptrow = pkv.pt
        if sub_extra:
            self._extra_cache = jax.tree_util.tree_map(
                lambda c, u: jax.lax.dynamic_update_slice_in_dim(
                    c, u, s, axis=1),
                self._extra_cache, sub_extra)
        self.prefill_stepwise_tokens += P - start
        return int(jnp.argmax(logits[0]))

    # -- contiguous prefill (paged=False) -------------------------------------

    def _prefill_len(self, P: int) -> int:
        if self.exec is not None:
            # sparse plans prefill at a bucketed length too: the row tables
            # slice to the first Sp/block row-blocks (_sparse_prefill_exec),
            # so admission cost is O(prompt bucket), not O(plan coverage).
            # (The fused path is causal-only — _can_fuse — so the slice is
            # always self-contained.)
            blk = self.exec.block
            b = ((max(self.prefill_bucket, blk) + blk - 1) // blk) * blk
            return min(max(((P + b - 1) // b) * b, b), self.exec.coverage)
        b = self.prefill_bucket
        if self.paged:
            # paged inserts scatter whole pages: bucket to page multiples
            b = ((b + self.page - 1) // self.page) * self.page
        return max(((P + b - 1) // b) * b, b)

    def _sparse_prefill_exec(self, Sp: int):
        """The prefill-phase exec for a padded prompt of length Sp: slice
        the stacked forward tables to the first Sp/block row-blocks —
        every listed column of a causal row r is <= r, so the sliced
        tables are self-contained (the fused path is causal-only). The
        transposed row_idx/nvalid_t are dropped rather than re-sliced:
        they only feed the fused kernel's dK/dV backward grid, and serving
        prefill never differentiates."""
        ex = self._prefill_exec
        if Sp >= ex.coverage:
            return ex
        nrb = Sp // ex.block
        tabs = {"col_idx": ex.tables["col_idx"][:, :nrb],
                "nvalid": ex.tables["nvalid"][:, :nrb]}
        return SparseAttentionExec(tabs, block=ex.block, halo=ex.halo,
                                   phase="prefill", kernel=ex.kernel)

    def _prefill_into(self, r: Request, s: int) -> int:
        """Contiguous-cache prefill of request `r` into slot `s`; returns
        its first generated token (argmax of the last prompt position's
        logits — which is when t_first is stamped, per request)."""
        P = len(r.prompt)
        if self._can_fuse:
            Sp = self._prefill_len(P)
            toks = np.zeros((1, Sp), np.int32)
            toks[0, :P] = r.prompt
            pex = None if self._prefill_exec is None \
                else self._sparse_prefill_exec(Sp)
            logits, ks, vs = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)}, pex)
            self.cache = self._insert(self.cache, ks, vs, jnp.int32(s),
                                      jnp.int32(P))
            self.prefill_fused += 1
            return int(jnp.argmax(logits[0, P - 1]))
        # stepwise fallback (ssm states, vlm): teacher-force the prompt
        # through a FRESH B=1 cache — per-request, so no other slot is
        # touched and no stale state leaks in — then write the slot slice
        step1 = self._decode1_step()
        sub = self.bundle.init_cache(1, self.max_len)
        logits = None
        for t in range(P):
            logits, sub = step1(
                self.params, sub, jnp.asarray([[r.prompt[t]]], np.int32),
                jnp.int32(t), *self._ex_args())
        self.cache = jax.tree_util.tree_map(
            lambda c, u: jax.lax.dynamic_update_slice_in_dim(c, u, s, axis=1),
            self.cache, sub)
        self.prefill_stepwise_tokens += P
        return int(jnp.argmax(logits[0]))

    def _insert_fn(self, cache, ks, vs, slot, plen):
        """Write a prefilled request's K/V stack (L, 1, Sp, KV, hd) into
        cache slot `slot`. Append caches take positions [0, min(Sp, S));
        sliding-window ring caches take, for each ring slot s, the LATEST
        prompt position congruent to s (mod S) — the same layout the
        decode-time ring writer produces."""
        kc, vc = cache["k"], cache["v"]
        L, S = kc.shape[0], kc.shape[2]
        Sp = ks.shape[2]
        if self.cfg.sliding_window:
            s = jnp.arange(S)
            p = s + ((plen - 1 - s) // S) * S     # latest pos = s (mod S), < plen
            valid = (p >= 0) & (p < Sp)
            pc = jnp.clip(p, 0, Sp - 1)
            knew = jnp.take(ks, pc, axis=2).astype(kc.dtype)
            vnew = jnp.take(vs, pc, axis=2).astype(vc.dtype)
            tail = kc.shape[3:]
            old_k = jax.lax.dynamic_slice(kc, (0, slot, 0, 0, 0), (L, 1, S) + tail)
            old_v = jax.lax.dynamic_slice(vc, (0, slot, 0, 0, 0), (L, 1, S) + tail)
            m = valid[None, None, :, None, None]
            knew = jnp.where(m, knew, old_k)
            vnew = jnp.where(m, vnew, old_v)
        else:
            take = min(Sp, S)
            knew = ks[:, :, :take].astype(kc.dtype)
            vnew = vs[:, :, :take].astype(vc.dtype)
        kc = jax.lax.dynamic_update_slice(kc, knew, (0, slot, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, vnew, (0, slot, 0, 0, 0))
        return dict(cache, k=kc, v=vc)
