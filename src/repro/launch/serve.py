"""Continuous-batching serving engine over a slot-structured KV cache.

Each engine tick admits waiting requests into free cache slots — fused
prefill (make_prefill_step(with_cache=True): one full-sequence forward whose
per-layer RoPE'd K/V are inserted straight into the slot) — then decodes ONE
token for every active slot in a single batched decode_step with PER-SLOT
positions: requests of different lengths decode at their own offsets, finish
independently, and their slots are reclaimed and refilled mid-decode.

Sparse serving (DESIGN.md §11): pass the training run's SparsityPlan (or its
tables payload) as `spion=` and both phases use it — the prefill runs the
same block-sparse attention the sparse training phase runs, and decode
gathers only the cache blocks the query position's pattern row lists
(core.sparse_attention.sparse_decode_attention), composing with the
sliding-window ring buffer. The plan must cover the positions the engine
will ever decode (`SparseAttentionExec.coverage >= prompt + max_new`).

Cache hygiene, by construction rather than by care:
  - prefill is per-request (B=1) and the batched decode writes each row at
    its own slot/position (models.attention.update_cache vector form), so
    one request can never write into another's cache row — the old engine's
    padded-prompt pollution (shorter prompts re-feeding their last token
    every tick) is structurally impossible;
  - padding junk the fused prefill writes past the prompt length is dead:
    a position is only ever read after the decode loop has overwritten it
    (every decode tick writes its K/V at `pos` before attending), and ring
    slots holding stale positions are masked by the ring position
    arithmetic.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attention_exec import SparseAttentionExec
from repro.core.sparse_attention import SparsityPlan
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models.registry import build


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (P,) int32
    max_new: int = 16
    out: Optional[list] = None
    done: bool = False
    slot: Optional[int] = None
    t_submit: float = 0.0
    t_first: float = 0.0         # stamped when THIS request's first token lands
    t_done: float = 0.0


class ServeEngine:
    """Continuous batching with per-slot positions and fused prefill.

    spion: None | SparsityPlan | tables payload | SparseAttentionExec —
    enables sparse prefill AND pattern-bounded sparse decode from the same
    layer-wise plan the training run produced.
    prefill_bucket: prompts pad up to a multiple of this before the fused
    prefill (bounding jit retraces to one per bucket); causality makes the
    padding free and the junk K/V it writes is never read (see module
    docstring). Sparse plans prefill at the same bucketed length — the
    stacked row tables slice to the prompt's row-blocks
    (_sparse_prefill_exec; self-contained because the fused path is
    causal-only), so admission stays O(prompt), not O(plan coverage).
    Families without a plain KV cache (ssm/hybrid) prefill stepwise into a
    fresh B=1 cache that is then written into the slot — per-request, so
    mixed prompt lengths still cannot cross-pollute.
    """

    def __init__(self, cfg, params, *, slots=4, max_len=512, spion=None,
                 prefill_bucket=32):
        self.cfg = cfg
        self.bundle = build(cfg)
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.prefill_bucket = prefill_bucket

        self.exec: Optional[SparseAttentionExec] = None
        self._prefill_exec = None
        if spion is not None:
            if isinstance(spion, SparsityPlan):
                ex = SparseAttentionExec.from_plan(spion, phase="decode")
            else:
                ex = SparseAttentionExec.coerce(spion, phase="decode")
            self.exec = ex
            self._prefill_exec = SparseAttentionExec.coerce(ex, phase="prefill")

        self.cache = self.bundle.init_cache(slots, max_len)
        # per-slot NEXT decode position. Freeness is `active[s] is None`;
        # a reclaimed slot's pos stays parked at its final value — the
        # batched decode still writes an (unread) K/V row for idle slots
        # each tick, and parking it at the one position the finished
        # request never wrote (P + max_new - 1: the last generated token is
        # never fed back) keeps the request's written cache region
        # byte-stable after completion instead of scribbling on position 0.
        self.pos = np.full((slots,), -1, np.int64)
        self.active: List[Optional[Request]] = [None] * slots
        self.waiting: Deque[Request] = collections.deque()

        self._decode = jax.jit(
            make_serve_step(cfg, spion=True), donate_argnums=(1,))
        self._can_fuse = (self.bundle.prefill_kv is not None and cfg.causal
                          and not cfg.num_patch_tokens)
        if self._can_fuse:
            self._prefill = jax.jit(
                make_prefill_step(cfg, spion=True, with_cache=True))
            self._insert = jax.jit(self._insert_fn, donate_argnums=(0,))
        else:
            self._decode1 = jax.jit(make_serve_step(cfg, spion=True))

    # -- request lifecycle ----------------------------------------------------

    def submit(self, req: Request):
        """Queue a request; it is admitted into a slot (prefilled) at the
        next engine tick with one free."""
        req.t_submit = time.time()
        req.out = []
        P = len(req.prompt)
        if P < 1:
            raise ValueError("prompt must have at least one token (the first "
                             "generated token is the argmax at its last "
                             "position)")
        if req.max_new < 1:
            raise ValueError("max_new must be >= 1")
        if not self.cfg.sliding_window and P + req.max_new > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt ({P}) + max_new ({req.max_new}) "
                f"exceeds the cache length ({self.max_len})")
        if self.exec is not None and P + req.max_new > self.exec.coverage:
            raise ValueError(
                f"request {req.rid}: prompt ({P}) + max_new ({req.max_new}) "
                f"exceeds the sparsity plan's coverage "
                f"({self.exec.coverage} positions = nrb * block); build the "
                f"plan at the serving sequence length")
        self.waiting.append(req)

    def step(self):
        """One engine tick: admit waiting requests into free slots (each one
        prefilled into its slot), then decode one token for every active
        slot at its own position."""
        self._admit()
        if any(r is not None for r in self.active):
            self._decode_tick()

    def run(self, requests: List[Request]):
        """Drive `requests` (any count vs slot count) to completion."""
        for r in requests:
            self.submit(r)
        while self.waiting or any(r is not None for r in self.active):
            self.step()
        return requests

    # -- internals ------------------------------------------------------------

    def _admit(self):
        for s in range(self.slots):
            if self.waiting and self.active[s] is None:
                r = self.waiting.popleft()
                first = self._prefill_into(r, s)
                r.slot = s
                r.out.append(first)
                r.t_first = time.time()
                self.active[s] = r
                self.pos[s] = len(r.prompt)
                if len(r.out) >= r.max_new:
                    self._finish(r, s)

    def _finish(self, r: Request, s: int):
        r.done = True
        r.t_done = time.time()
        self.active[s] = None

    def _decode_tick(self):
        tok = np.zeros((self.slots, 1), np.int32)
        posv = np.zeros((self.slots,), np.int32)
        for s, r in enumerate(self.active):
            posv[s] = max(self.pos[s], 0)   # idle slots park (see __init__)
            if r is not None:
                tok[s, 0] = r.out[-1]
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tok), jnp.asarray(posv),
            self.exec)
        nxt = np.asarray(jnp.argmax(logits, -1))
        for s, r in enumerate(self.active):
            if r is None:
                continue
            r.out.append(int(nxt[s]))
            self.pos[s] += 1
            if len(r.out) >= r.max_new:
                self._finish(r, s)

    def _prefill_len(self, P: int) -> int:
        if self.exec is not None:
            # sparse plans prefill at a bucketed length too: the row tables
            # slice to the first Sp/block row-blocks (_sparse_prefill_exec),
            # so admission cost is O(prompt bucket), not O(plan coverage).
            # (The fused path is causal-only — _can_fuse — so the slice is
            # always self-contained.)
            blk = self.exec.block
            b = ((max(self.prefill_bucket, blk) + blk - 1) // blk) * blk
            return min(max(((P + b - 1) // b) * b, b), self.exec.coverage)
        b = self.prefill_bucket
        return max(((P + b - 1) // b) * b, b)

    def _sparse_prefill_exec(self, Sp: int):
        """The prefill-phase exec for a padded prompt of length Sp: slice
        the stacked forward tables to the first Sp/block row-blocks —
        every listed column of a causal row r is <= r, so the sliced
        tables are self-contained (the fused path is causal-only). The
        transposed row_idx/nvalid_t are dropped rather than re-sliced:
        they only feed the fused kernel's dK/dV backward grid, and serving
        prefill never differentiates."""
        ex = self._prefill_exec
        if Sp >= ex.coverage:
            return ex
        nrb = Sp // ex.block
        tabs = {"col_idx": ex.tables["col_idx"][:, :nrb],
                "nvalid": ex.tables["nvalid"][:, :nrb]}
        return SparseAttentionExec(tabs, block=ex.block, halo=ex.halo,
                                   phase="prefill", kernel=ex.kernel)

    def _prefill_into(self, r: Request, s: int) -> int:
        """Prefill request `r` into cache slot `s`; returns its first
        generated token (argmax of the last prompt position's logits —
        which is when t_first is stamped, per request)."""
        P = len(r.prompt)
        if self._can_fuse:
            Sp = self._prefill_len(P)
            toks = np.zeros((1, Sp), np.int32)
            toks[0, :P] = r.prompt
            pex = None if self._prefill_exec is None \
                else self._sparse_prefill_exec(Sp)
            logits, ks, vs = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)}, pex)
            self.cache = self._insert(self.cache, ks, vs, jnp.int32(s),
                                      jnp.int32(P))
            return int(jnp.argmax(logits[0, P - 1]))
        # stepwise fallback (ssm/hybrid states): teacher-force the prompt
        # through a FRESH B=1 cache — per-request, so no other slot is
        # touched and no stale state leaks in — then write the slot slice
        sub = self.bundle.init_cache(1, self.max_len)
        logits = None
        for t in range(P):
            logits, sub = self._decode1(
                self.params, sub, jnp.asarray([[r.prompt[t]]], np.int32),
                jnp.int32(t), self.exec)
        self.cache = jax.tree_util.tree_map(
            lambda c, u: jax.lax.dynamic_update_slice_in_dim(c, u, s, axis=1),
            self.cache, sub)
        return int(jnp.argmax(logits[0]))

    def _insert_fn(self, cache, ks, vs, slot, plen):
        """Write a prefilled request's K/V stack (L, 1, Sp, KV, hd) into
        cache slot `slot`. Append caches take positions [0, min(Sp, S));
        sliding-window ring caches take, for each ring slot s, the LATEST
        prompt position congruent to s (mod S) — the same layout the
        decode-time ring writer produces."""
        kc, vc = cache["k"], cache["v"]
        L, S = kc.shape[0], kc.shape[2]
        Sp = ks.shape[2]
        if self.cfg.sliding_window:
            s = jnp.arange(S)
            p = s + ((plen - 1 - s) // S) * S     # latest pos = s (mod S), < plen
            valid = (p >= 0) & (p < Sp)
            pc = jnp.clip(p, 0, Sp - 1)
            knew = jnp.take(ks, pc, axis=2).astype(kc.dtype)
            vnew = jnp.take(vs, pc, axis=2).astype(vc.dtype)
            tail = kc.shape[3:]
            old_k = jax.lax.dynamic_slice(kc, (0, slot, 0, 0, 0), (L, 1, S) + tail)
            old_v = jax.lax.dynamic_slice(vc, (0, slot, 0, 0, 0), (L, 1, S) + tail)
            m = valid[None, None, :, None, None]
            knew = jnp.where(m, knew, old_k)
            vnew = jnp.where(m, vnew, old_v)
        else:
            take = min(Sp, S)
            knew = ks[:, :, :take].astype(kc.dtype)
            vnew = vs[:, :, :take].astype(vc.dtype)
        kc = jax.lax.dynamic_update_slice(kc, knew, (0, slot, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, vnew, (0, slot, 0, 0, 0))
        return dict(cache, k=kc, v=vc)
