"""Batched serving driver: synchronous continuous batching over a KV cache.

Requests queue up; each engine tick either prefills a waiting request into a
free cache slot or decodes one token for every active slot. The decode step
is the same serve_step the dry-run lowers for decode_32k / long_500k.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import build


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (P,) int32
    max_new: int = 16
    out: Optional[list] = None
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class ServeEngine:
    """Slot-based batched decode; prefill via repeated decode_step (prefill
    jit) for simplicity — a production engine would use the fused prefill."""

    def __init__(self, cfg, params, *, slots=4, max_len=512):
        self.cfg = cfg
        self.bundle = build(cfg)
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = self.bundle.init_cache(slots, max_len)
        self.pos = np.zeros((slots,), np.int64) - 1  # -1 = free
        self.active: List[Optional[Request]] = [None] * slots
        self._decode = jax.jit(self.bundle.decode_step, donate_argnums=(1,))

    def submit(self, req: Request):
        req.t_submit = time.time()
        for s in range(self.slots):
            if self.active[s] is None:
                self.active[s] = req
                req.out = []
                self.pos[s] = 0
                return s
        raise RuntimeError("no free slot")

    def _step_token(self, tokens, pos):
        """tokens (slots,1); single shared pos per tick (synchronous)."""
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens), jnp.int32(pos))
        return np.asarray(jnp.argmax(logits, -1))

    def run(self, requests: List[Request], greedy=True):
        """Synchronous batch: all requests padded to the same prompt cadence."""
        for r in requests:
            self.submit(r)
        maxp = max(len(r.prompt) for r in requests)
        # prefill (token-by-token teacher forcing into the caches)
        tok = np.zeros((self.slots, 1), np.int32)
        nxt = np.zeros((self.slots,), np.int32)
        for t in range(maxp):
            for s, r in enumerate(self.active):
                if r is not None:
                    tok[s, 0] = r.prompt[min(t, len(r.prompt) - 1)]
            nxt = self._step_token(tok, t)
        for r in requests:
            r.t_first = time.time()
        # decode
        for j in range(max(r.max_new for r in requests)):
            for s, r in enumerate(self.active):
                if r is not None and not r.done:
                    tok[s, 0] = nxt[s]
                    r.out.append(int(nxt[s]))
                    if len(r.out) >= r.max_new:
                        r.done = True
                        r.t_done = time.time()
            if all(r is None or r.done for r in self.active):
                break
            nxt = self._step_token(tok, maxp + j)
        for s in range(self.slots):
            self.active[s] = None
            self.pos[s] = -1
        return requests
