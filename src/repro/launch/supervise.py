"""CLI for the self-healing fleet supervisor (DESIGN.md §13).

    python -m repro.launch.supervise --nproc 2 --ckpt-dir /ckpt \\
        [--dead-timeout 60] [--hang-timeout 120] [--max-respawns 5] \\
        -- python -m repro.launch.train --ckpt-dir /ckpt --steps 10000

Everything after ``--`` is the worker command, run once per process with
SPION_COORDINATOR / SPION_NUM_PROCESSES / SPION_PROCESS_ID injected (fresh
coordinator port per generation). The supervisor watches the heartbeat
files under --ckpt-dir and respawns the whole fleet — resuming from the
last committed checkpoint — whenever a worker dies, exits non-zero, or
freezes its step counter. Exit 0: all workers completed; exit 1: respawn
budget exhausted.
"""
from __future__ import annotations

import argparse
import sys

from repro.distributed.supervisor import FleetSupervisor


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--" in argv:
        split = argv.index("--")
        argv, worker_cmd = argv[:split], argv[split + 1:]
    else:
        worker_cmd = []
    ap = argparse.ArgumentParser(
        description="heartbeat-driven fleet supervisor with auto-respawn")
    ap.add_argument("--nproc", type=int, required=True)
    ap.add_argument("--ckpt-dir", required=True,
                    help="checkpoint dir; also where the hb_* files live")
    ap.add_argument("--dead-timeout", type=float, default=60.0,
                    help="seconds without a heartbeat write before a worker "
                         "is declared dead")
    ap.add_argument("--hang-timeout", type=float, default=120.0,
                    help="seconds without step progress (while the heartbeat "
                         "stays fresh) before a worker is declared hung; "
                         "must exceed the longest legitimate stall "
                         "(sparse-step compile at the phase transition)")
    ap.add_argument("--poll-interval", type=float, default=1.0)
    ap.add_argument("--max-respawns", type=int, default=5)
    ap.add_argument("--backoff-base", type=float, default=1.0)
    ap.add_argument("--backoff-max", type=float, default=30.0)
    ap.add_argument("--straggler-limit", type=int, default=None,
                    help="respawn when a worker self-reports this many "
                         "straggler steps (off by default)")
    args = ap.parse_args(argv)
    if not worker_cmd:
        ap.error("missing worker command: ... -- <worker argv>")
    sup = FleetSupervisor(
        worker_cmd, args.nproc, args.ckpt_dir,
        dead_timeout=args.dead_timeout, hang_timeout=args.hang_timeout,
        poll_interval=args.poll_interval, max_respawns=args.max_respawns,
        backoff_base=args.backoff_base, backoff_max=args.backoff_max,
        straggler_limit=args.straggler_limit)
    return sup.run()


if __name__ == "__main__":
    sys.exit(main())
