"""Production mesh definitions.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import).

Single pod:  (data=16, model=16)            = 256 chips (TPU v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips
The `pod` axis composes with `data` for DP by default and can host pipeline
stages (distributed/pipeline.py).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.4.38; older releases have no explicit axis types
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def make_mesh(shape, axes):
    """`jax.make_mesh` with explicit-Auto axis types where the API has them."""
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False, seq: int = 1):
    """The pod meshes. `seq > 1` carves a sequence-parallel axis out of the
    data axis (chip count unchanged): long-context sparse training shards Q
    row-blocks over 'seq' with a pattern-bounded halo exchange
    (kernels/sharded.py, DESIGN.md §10) while dense ops keep GSPMD."""
    if seq > 1:
        if 16 % seq:
            raise ValueError(f"seq={seq} must divide the data axis (16)")
        shape = (2, seq, 16 // seq, 16) if multi_pod else (seq, 16 // seq, 16)
        axes = (("pod", "seq", "data", "model") if multi_pod
                else ("seq", "data", "model"))
        return make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_seq_mesh(seq: int, data: int = 1, model: int = 1):
    """Small explicit (seq, data, model) mesh — tests / virtual-device CI.
    Axes of size 1 are kept (the names drive the dispatch, not the sizes)
    except model, dropped when 1 to mirror the common 2-axis test meshes."""
    if model > 1:
        return make_mesh((seq, data, model), ("seq", "data", "model"))
    return make_mesh((seq, data), ("seq", "data"))


def make_host_mesh():
    """Whatever this host offers (CPU CI: 1 device) as a (data, model) mesh."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))


def make_distributed_mesh(*, model: int = 1, seq: int = 1,
                          data: int | None = None):
    """Process-spanning mesh for a `jax.distributed` job: the 'pod' axis is
    exactly the process axis (batch data-parallelism across hosts — the
    highest-latency fabric carries only the gradient all-reduce), and
    seq/data/model fill each process's local devices (seq/model collectives
    stay on the intra-host fabric).

    Built by reshaping `jax.devices()` directly rather than via
    mesh_utils.create_device_mesh: jax's global device order is
    process-major, so the leading reshape axis IS the process boundary —
    the property the single-controller broadcast, the checkpoint commit
    barrier and the halo-exchange locality analysis all assume. An ICI-
    optimising permutation that traded that alignment away for torus
    locality would silently put 'pod' neighbours on different hosts.

    Degrades cleanly to single-process (pod=1): the same axis names, so
    pspecs and dispatch decisions are identical between a CI virtual-device
    run and a real multi-host launch."""
    import numpy as np
    nproc = jax.process_count()
    nloc = jax.local_device_count()
    per = seq * model
    if data is None:
        if nloc % per:
            raise ValueError(
                f"local device count {nloc} not divisible by "
                f"seq*model={per}")
        data = nloc // per
    if seq * data * model != nloc:
        raise ValueError(
            f"seq*data*model = {seq}*{data}*{model} != local device "
            f"count {nloc}")
    devs = np.asarray(jax.devices())
    if seq > 1:
        shape, axes = ((nproc, seq, data, model),
                       ("pod", "seq", "data", "model"))
    else:
        shape, axes = (nproc, data, model), ("pod", "data", "model")
    from jax.sharding import Mesh
    return Mesh(devs.reshape(shape), axes)


# Hardware constants for the roofline (TPU v5e per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW_PER_LINK = 50e9        # B/s per link
