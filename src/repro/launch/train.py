"""End-to-end training driver with the full production loop:

  data pipeline -> jitted train step (dense phase) -> SPION capture between
  epochs -> Frobenius transition -> pattern generation -> sparse phase ->
  checkpoints (atomic, async, keep-K) -> crash-restart supervisor ->
  straggler monitor.

CPU-runnable at reduced scale (examples/ wire it up); identical code paths
lower onto the production meshes (launch/dryrun.py proves compile).

Multi-host (DESIGN.md §12): launch one process per host with the SPION_*
env vars (or --coordinator/--num-processes/--process-id), and the same loop
becomes a fleet: `repro.distributed.runtime` joins jax.distributed, the mesh
gains a process-spanning 'pod' axis (make_distributed_mesh), flood-fill runs
single-controller on process 0 with the plan broadcast + digest-checked,
checkpoints are process-0-written/all-read with a commit barrier, and a
SIGTERM on any host triggers a fleet-wide same-step save and clean exit
(elastic resume onto a different process count re-shards from the
mesh-agnostic checkpoint and rebuilds the execs from the restored plan).

Self-healing (DESIGN.md §13): a DivergenceSentinel checks every step's loss
for NaN/inf and EWMA spikes; the flag rides the same per-step `any_flags`
OR as preemption, so the whole fleet rolls back at the SAME step to the
last *good* (pinned) checkpoint, skips the offending data window, and
hard-fails after `max_rollbacks` consecutive rollbacks. Run unattended
under `python -m repro.launch.supervise`, which scans the per-process
heartbeat files (JSON {ts, step, phase, ...}) and respawns the fleet when
a worker dies or its step counter freezes.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core.spion import SpionController, SpionState
from repro.data.synthetic import lm_batch_iterator
from repro.distributed import runtime
from repro.distributed.chaos import ChaosMonkey
from repro.distributed.fault import (DivergenceSentinel, Heartbeat,
                                     StepSupervisor, StragglerMonitor)
from repro.distributed.sharding import mesh_context, param_shardings
from repro.launch.mesh import make_distributed_mesh
from repro.launch.steps import batch_pspecs, make_train_step
from repro.models.registry import build
from repro.optim import adamw_init

# XLA flags for real TPU runs (latency-hiding scheduler = compute/comm overlap)
TPU_XLA_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_megacore_fusion_allow_ags=true "
    "--xla_enable_async_collective_permute=true "
    "--xla_tpu_overlap_compute_collective_tc=true"
)


def masters_of(params):
    return jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.ndim >= 2 else x, params)


class Trainer:
    def __init__(self, cfg, *, seq_len, batch, lr=3e-4, total_steps=1000,
                 ckpt_dir=None, mesh=None, seed=0, steps_per_epoch=50,
                 data_iter=None, data_fn=None, capture_batches=1,
                 sparse_kernel=None, chaos=None, heartbeat_interval=5.0,
                 sentinel=None, max_rollbacks=3, step_callback=None):
        self.cfg = cfg
        self.bundle = build(cfg)
        self.mesh = mesh
        self.seq_len = seq_len
        self.steps_per_epoch = steps_per_epoch
        self.spion_ctl = SpionController(cfg.spion, causal=cfg.causal, seq_len=seq_len)
        self.spion_state = SpionState()
        self.monitor = StragglerMonitor()
        self.ckpt = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
        self.step = 0
        # `data_fn(step) -> host batch` is the fault-tolerant data contract:
        # step-indexed, so a resume replays the EXACT batch sequence the
        # uninterrupted run would have seen (a bare iterator restarts from
        # its beginning after a crash — fine for smoke runs, wrong for
        # step-exact recovery). In multi-process runs data_fn must return
        # the same *global* batch on every process; the 'pod'/'data' slice
        # each device keeps is carved off when the batch goes global.
        self.data_fn = data_fn
        if data_fn is None:
            rng = np.random.default_rng(seed)
            self.data = data_iter if data_iter is not None else lm_batch_iterator(
                rng, batch=batch, seq_len=seq_len + 1, vocab=cfg.vocab_size)
        # fault machinery: chaos is env-armed by default (inert when the
        # launcher sets no SPION_CHAOS_* vars); preemption flag set by the
        # SIGTERM handler (install_preemption_handler) and OR-reduced
        # across processes at each step boundary so the save runs in
        # lockstep
        self.chaos = chaos if chaos is not None else ChaosMonkey.from_env()
        self._preempted = False
        self.preempted = False          # observable: loop exited via preemption
        # divergence sentinel (DESIGN.md §13): default-on loss health check;
        # pass sentinel=False to disable. The local flag is OR-reduced
        # fleet-wide each step alongside preemption (one collective for both)
        # so every process rolls back at the SAME step.
        self.sentinel = DivergenceSentinel() if sentinel is None else (sentinel or None)
        self.max_rollbacks = max_rollbacks
        self.step_callback = step_callback
        self.data_offset = 0            # data windows skipped by rollbacks
        self.good_step = None           # last checkpoint known loss-healthy
        self.rollback_count = 0         # observable: total rollbacks performed
        self.loss_history = {}          # step -> loss; replays overwrite (stitched)
        self.events = []                # structured fault events (also printed)
        self._diverged_pending = False
        self._diverge_step = None
        self._last_diverge_step = None
        self._rollback_streak = 0       # consecutive rollbacks w/o healthy progress
        self._straggler_steps = 0
        self.heartbeat = None
        if ckpt_dir:
            self.heartbeat = Heartbeat(
                os.path.join(ckpt_dir, f"hb_{runtime.process_index()}"),
                interval=heartbeat_interval)

        params = self.bundle.init(jax.random.key(seed))
        self.params = masters_of(params)
        self.opt = adamw_init(self.params)

        self._dense_step = jax.jit(make_train_step(
            cfg, spion=False, lr=lr, total_steps=total_steps), donate_argnums=(0, 1))
        # one jitted sparse step for the whole run: the step receives a
        # SparseAttentionExec whose static block/halo ride the pytree
        # aux_data, so a NEW plan (different halo after a phase transition
        # or a sparse-phase resume) retraces automatically — no caller-side
        # halo tracking or lazy step rebuilds (DESIGN.md §11)
        self._sparse_step = jax.jit(make_train_step(
            cfg, spion=True, lr=lr, total_steps=total_steps,
            sparse_kernel=sparse_kernel), donate_argnums=(0, 1))
        capture_fn = lambda p, b, f, blk: self.bundle.forward(
            p, b, capture={"filt": f, "block": blk})[1]["captured"]
        if mesh is not None and runtime.process_count() > 1:
            # the capture stats feed the HOST-side flood-fill: with the mesh
            # spanning processes the outputs must come back fully
            # replicated, or np.asarray on a partially-addressable global
            # array would throw on every process but 0
            self._capture = jax.jit(capture_fn, static_argnames=("blk",),
                                    out_shardings=NamedSharding(mesh, P()))
        else:
            self._capture = jax.jit(capture_fn, static_argnames=("blk",))
        self.supervisor = StepSupervisor(self._restore_latest)

    # -- multi-process plumbing ----------------------------------------------

    def install_preemption_handler(self):
        """SIGTERM -> finish the in-flight step, then save and exit cleanly
        (the fleet-wide OR in the loop makes every process save at the SAME
        step even when the signal lands on one host). Main thread only."""
        def _handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, _handler)

    def _device_batch(self, batch):
        """Host batch -> device. Multi-process: every process holds the same
        global batch; build committed global arrays sharded over the
        'pod'/'data' axes so each device keeps only its slice."""
        if self.mesh is not None and runtime.process_count() > 1:
            return runtime.make_global(
                self.mesh, batch, batch_pspecs(self.cfg, batch, self.mesh))
        return {k: jnp.asarray(v) for k, v in batch.items()}

    def _next_batch(self):
        # `+ data_offset`: each divergence rollback advances the offset past
        # the poisoned window, so the replayed steps see FRESH data while
        # staying step-indexed (resume-exact) — DESIGN.md §13.
        b = self.data_fn(self.step + self.data_offset) if self.data_fn \
            else next(self.data)
        return self._device_batch(b)

    # -- checkpoint/restart --------------------------------------------------

    def _state_tree(self):
        return {"params": self.params, "opt": self.opt}

    def save(self):
        if not self.ckpt:
            return
        # plan tables go binary (extra_arrays) — the JSON extra keeps only
        # scalars, so a production-size SparsityPlan doesn't bloat meta.
        # In multi-process runs this is a collective (all-gather to host
        # on every process; process 0 writes) — every process calls it.
        # A step with a divergence flag pending is saved but NOT promoted to
        # good_step (its state is already poisoned); the rollback quarantines
        # it. `_diverged_pending` derives from the global-mean loss, so the
        # healthy/poisoned decision is identical on every process.
        healthy = not self._diverged_pending
        if healthy:
            # pin BEFORE the (async) write: _gc runs on the writer thread
            # and must already see the new good step as protected
            self.ckpt.pin(self.step)
        arrays = self.spion_state.table_arrays()
        self.ckpt.save(
            self.step, self._state_tree(),
            extra={"spion": self.spion_state.to_py(include_tables=False),
                   "step": self.step, "data_offset": self.data_offset},
            extra_arrays=None if arrays is None else
            {f"spion_{k}": v for k, v in arrays.items()})
        if healthy:
            if self.good_step is not None and self.good_step != self.step:
                self.ckpt.unpin(self.good_step)
            self.good_step = self.step
            if (self._last_diverge_step is not None
                    and self.step > self._last_diverge_step):
                self._rollback_streak = 0  # healthy progress past the spike

    def _restore_shardings(self):
        """Shardings for the state tree on the CURRENT mesh — the elastic
        half of recovery: the checkpoint is mesh-agnostic (fully gathered),
        and restore re-shards it for however many processes/devices this
        incarnation of the job has."""
        if self.mesh is None:
            return None
        psh = param_shardings(self.mesh, self.params)
        rep = NamedSharding(self.mesh, P())
        return {"params": psh,
                "opt": {"mu": psh, "nu": psh, "count": rep}}

    def _restore_latest(self, step=None):
        if not self.ckpt:
            return
        tree, got, extra = self.ckpt.restore(
            step=step, target=self._state_tree(),
            shardings=self._restore_shardings())
        if tree is not None:
            self.params, self.opt = tree["params"], tree["opt"]
            self.step = extra.get("step", got or 0)
            self.data_offset = int(extra.get("data_offset", 0))
            # whatever we restore from is by definition our rollback target
            # until a newer healthy save supersedes it — pin it so GC can't
            # age it out of the keep window while training runs past it
            self.good_step = self.step
            self.ckpt.pin(self.step)
            if extra.get("spion"):
                arrays = {k[len("spion_"):]: v
                          for k, v in extra.get("_arrays", {}).items()
                          if k.startswith("spion_")} or None
                self.spion_state = SpionState.from_py(extra["spion"], arrays)
                # every process read the checkpoint independently; a torn
                # read or mixed-up dir on one host must fail loudly, not
                # train through a different pattern (DESIGN.md §12)
                self.spion_ctl.verify_plan_sync(self.spion_state)

    def maybe_resume(self):
        if self.ckpt and self.ckpt.latest_step() is not None:
            self._restore_latest()
            return True
        return False

    # -- steps ----------------------------------------------------------------

    def _one_step(self, batch):
        ex = self.spion_ctl.attention_exec(self.spion_state)
        if ex is not None:
            self.params, self.opt, metrics = self._sparse_step(
                self.params, self.opt, batch, jnp.int32(self.step), ex)
        else:
            self.params, self.opt, metrics = self._dense_step(
                self.params, self.opt, batch, jnp.int32(self.step))
        self.step += 1
        return metrics

    def _epoch_boundary(self, batch):
        """SPION capture + transition check on a capture batch. Pattern
        generation inside observe_epoch is single-controller: process 0
        flood-fills, everyone receives the broadcast plan (core/spion.py)."""
        cap = self.spion_ctl.capture_kwargs(self.spion_state)
        if cap is None:
            self.spion_state.epoch += 1
            return
        pc = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.dtype(self.cfg.dtype)) if x.ndim >= 2 and
            x.dtype == jnp.float32 else x, self.params)
        pooled, frob = self._capture(pc, batch, cap["filt"], cap["block"])
        self.spion_state = self.spion_ctl.observe_epoch(
            self.spion_state, np.asarray(pooled), np.asarray(frob))

    def _poll_flags(self):
        """Fleet-wide (preempted, diverged) decision, same answer on every
        process at the same step. Both flags ride ONE allgather per step in
        multi-process runs (any_flags), and it runs on the main thread at
        the loop top — collectives must never interleave with training-step
        collectives, and every process must reach this point at the same
        step for the OR to be well-defined (DESIGN.md §13)."""
        if runtime.process_count() > 1:
            return tuple(runtime.any_flags(
                [self._preempted, self._diverged_pending]))
        return self._preempted, self._diverged_pending

    def _emit(self, kind: str, **fields):
        """Structured fault event: appended to self.events on every process,
        printed (one JSON line, `SPION_EVENT {...}`) by the coordinator only
        so a supervisor/launcher tailing stdout sees each event once."""
        ev = {"event": kind, "step": self.step, "process": runtime.process_index()}
        ev.update(fields)
        self.events.append(ev)
        if runtime.is_coordinator():
            print("SPION_EVENT " + json.dumps(ev), flush=True)

    def _poison_params(self):
        """Chaos NaN injection: overwrite this process's addressable shards
        of every float param with NaN. Purely local (no jit, no collective
        — only the armed process runs it); the NEXT real step spreads the
        poison fleet-wide through the gradient psum, which is exactly the
        divergence propagation model the sentinel exists for."""
        def leaf(x):
            x = x if isinstance(x, jax.Array) else jnp.asarray(x)
            if not jnp.issubdtype(x.dtype, jnp.floating):
                return x
            shards = [jax.device_put(
                np.full(s.data.shape, np.nan, dtype=x.dtype), s.device)
                for s in x.addressable_shards]
            return jax.make_array_from_single_device_arrays(
                x.shape, x.sharding, shards)
        self.params = jax.tree_util.tree_map(leaf, self.params)

    def _rollback(self, log):
        """Coordinated divergence rollback (DESIGN.md §13): every process
        reaches here at the same step (the any_flags OR), agrees on the
        fleet-wide divergence step (max over local observations), quarantines
        checkpoints saved after the last good step, restores the pinned good
        checkpoint, and skips the poisoned data window so the replay sees
        fresh batches. Hard-fails after `max_rollbacks` consecutive
        rollbacks with no healthy checkpoint in between — at that point the
        divergence is not data-borne and a human needs to look."""
        t0 = time.time()
        local_d = -1 if self._diverge_step is None else self._diverge_step
        d = runtime.max_value(local_d) if runtime.process_count() > 1 else local_d
        self.rollback_count += 1
        self._rollback_streak += 1
        if self._rollback_streak > self.max_rollbacks:
            raise RuntimeError(
                f"loss diverged through {self.max_rollbacks} consecutive "
                f"rollbacks (last at step {d}): not recoverable by replay")
        g = self.good_step
        if self.ckpt is None or g is None:
            raise RuntimeError(
                f"loss diverged at step {d} but there is no good checkpoint "
                "to roll back to (enable checkpointing / lower ckpt_every)")
        self.ckpt.quarantine_after(g)       # poisoned saves must never restore
        self._restore_latest(step=g)        # also restores data_offset as-of g
        skip = (d - g + 1) if d >= g else 1
        self.data_offset += skip
        self._diverged_pending = False
        self._diverge_step = None
        self._last_diverge_step = d
        if self.sentinel:
            self.sentinel.reset()           # don't inherit spike-adjacent EWMA
        self._emit("rollback", from_step=d, to_step=g, skip=skip,
                   data_offset=self.data_offset,
                   seconds=round(time.time() - t0, 3))
        log(f"divergence at step {d}: rolled back to step {g}, skipping "
            f"data window [{g}, {d}] (offset now {self.data_offset}, "
            f"streak {self._rollback_streak}/{self.max_rollbacks})")

    def train(self, num_steps, *, ckpt_every=100, log_every=10, log=print):
        log0 = log if runtime.is_coordinator() else (lambda *a, **k: None)
        if self.heartbeat:
            self.heartbeat.pulse()          # announce liveness immediately
            self.heartbeat.start_thread()   # keeps ts fresh even mid-step
        try:
            with mesh_context(self.mesh):
                return self._train_loop(num_steps, ckpt_every, log_every, log0)
        finally:
            if self.heartbeat:
                self.heartbeat.stop_thread()

    def _train_loop(self, num_steps, ckpt_every, log_every, log0):
        t_total = time.time()
        losses = []
        target = self.step + num_steps
        while self.step < target:
            if self.chaos:
                self.chaos.maybe_kill(self.step)
                self.chaos.maybe_hang(self.step)
            preempted, diverged = self._poll_flags()
            if diverged:
                self._rollback(log0)
                continue
            if preempted:
                self.preempted = True
                self.save()
                if self.ckpt:
                    self.ckpt.wait()
                log0(f"preempted: saved step {self.step}, exiting")
                return losses
            batch = self._next_batch()
            if self.chaos and self.chaos.poison_due(self.step):
                self._poison_params()
            t0 = time.time()
            metrics = self.supervisor.run(self._one_step, batch)
            dt = time.time() - t0
            loss = float(metrics["loss"])
            losses.append(loss)
            self.loss_history[self.step - 1] = loss  # replay overwrites: stitched
            if self.sentinel and self.sentinel.observe(loss):
                # local observation only; the fleet decision is the OR at
                # the top of the NEXT iteration, so every process rolls
                # back at the same step
                self._diverged_pending = True
                self._diverge_step = self.step - 1
                self._emit("divergence", loss=loss,
                           streak=self._rollback_streak)
            straggler = self.monitor.observe(dt)
            if straggler:
                self._straggler_steps += 1
                self._emit("straggler", dt=round(dt, 4),
                           total=self._straggler_steps)
            if self.heartbeat:
                self.heartbeat.beat(step=self.step,
                                    phase=self.spion_state.phase,
                                    extra={"stragglers": self._straggler_steps})
            if self.step_callback:
                self.step_callback(self.step - 1, loss)
            if self.step % log_every == 0:
                log0(f"step {self.step} loss {np.mean(losses[-log_every:]):.4f} "
                     f"phase {self.spion_state.phase} dt {dt*1e3:.0f}ms"
                     + (" [straggler]" if straggler else ""))
            if self.step % self.steps_per_epoch == 0 and not self._diverged_pending:
                # a poisoned epoch boundary would flood-fill NaN capture
                # stats; the imminent rollback replays the boundary from
                # healthy state anyway (same decision on every process:
                # the flag derives from the global-mean loss)
                self._epoch_boundary(batch)
            if ckpt_every and self.step % ckpt_every == 0:
                self.save()
        self.save()
        if self.ckpt:
            self.ckpt.wait()
        log0(f"done: {num_steps} steps in {time.time()-t_total:.1f}s, "
             f"final phase={self.spion_state.phase} density={self.spion_state.density}")
        return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="spion-lra")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--sparse-kernel", default=None,
                    choices=["auto", "jnp", "fused"],
                    help="sparse-phase attention impl (default: cfg.spion.kernel; "
                         "auto = fused Pallas kernel where a compiled lane or "
                         "shardable mesh dim exists, jnp path elsewhere)")
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT",
                    help="jax.distributed coordinator (or env SPION_COORDINATOR); "
                         "with --num-processes/--process-id this process joins "
                         "a multi-host job")
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    args = ap.parse_args()
    distributed = runtime.initialize(args.coordinator, args.num_processes,
                                     args.process_id)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_distributed_mesh() if distributed else None
    tr = Trainer(cfg, seq_len=args.seq_len, batch=args.batch,
                 ckpt_dir=args.ckpt_dir, mesh=mesh,
                 sparse_kernel=args.sparse_kernel)
    tr.install_preemption_handler()
    tr.maybe_resume()
    tr.train(args.steps)


if __name__ == "__main__":
    main()
