"""End-to-end training driver with the full production loop:

  data pipeline -> jitted train step (dense phase) -> SPION capture between
  epochs -> Frobenius transition -> pattern generation -> sparse phase ->
  checkpoints (atomic, async, keep-K) -> crash-restart supervisor ->
  straggler monitor.

CPU-runnable at reduced scale (examples/ wire it up); identical code paths
lower onto the production meshes (launch/dryrun.py proves compile).

Multi-host (DESIGN.md §12): launch one process per host with the SPION_*
env vars (or --coordinator/--num-processes/--process-id), and the same loop
becomes a fleet: `repro.distributed.runtime` joins jax.distributed, the mesh
gains a process-spanning 'pod' axis (make_distributed_mesh), flood-fill runs
single-controller on process 0 with the plan broadcast + digest-checked,
checkpoints are process-0-written/all-read with a commit barrier, and a
SIGTERM on any host triggers a fleet-wide same-step save and clean exit
(elastic resume onto a different process count re-shards from the
mesh-agnostic checkpoint and rebuilds the execs from the restored plan).
"""
from __future__ import annotations

import argparse
import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core.spion import SpionController, SpionState
from repro.data.synthetic import lm_batch_iterator
from repro.distributed import runtime
from repro.distributed.chaos import ChaosMonkey
from repro.distributed.fault import Heartbeat, StepSupervisor, StragglerMonitor
from repro.distributed.sharding import mesh_context, param_shardings
from repro.launch.mesh import make_distributed_mesh
from repro.launch.steps import batch_pspecs, make_train_step
from repro.models.registry import build
from repro.optim import adamw_init

# XLA flags for real TPU runs (latency-hiding scheduler = compute/comm overlap)
TPU_XLA_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_megacore_fusion_allow_ags=true "
    "--xla_enable_async_collective_permute=true "
    "--xla_tpu_overlap_compute_collective_tc=true"
)


def masters_of(params):
    return jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.ndim >= 2 else x, params)


class Trainer:
    def __init__(self, cfg, *, seq_len, batch, lr=3e-4, total_steps=1000,
                 ckpt_dir=None, mesh=None, seed=0, steps_per_epoch=50,
                 data_iter=None, data_fn=None, capture_batches=1,
                 sparse_kernel=None, chaos=None, heartbeat_interval=5.0):
        self.cfg = cfg
        self.bundle = build(cfg)
        self.mesh = mesh
        self.seq_len = seq_len
        self.steps_per_epoch = steps_per_epoch
        self.spion_ctl = SpionController(cfg.spion, causal=cfg.causal, seq_len=seq_len)
        self.spion_state = SpionState()
        self.monitor = StragglerMonitor()
        self.ckpt = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
        self.step = 0
        # `data_fn(step) -> host batch` is the fault-tolerant data contract:
        # step-indexed, so a resume replays the EXACT batch sequence the
        # uninterrupted run would have seen (a bare iterator restarts from
        # its beginning after a crash — fine for smoke runs, wrong for
        # step-exact recovery). In multi-process runs data_fn must return
        # the same *global* batch on every process; the 'pod'/'data' slice
        # each device keeps is carved off when the batch goes global.
        self.data_fn = data_fn
        if data_fn is None:
            rng = np.random.default_rng(seed)
            self.data = data_iter if data_iter is not None else lm_batch_iterator(
                rng, batch=batch, seq_len=seq_len + 1, vocab=cfg.vocab_size)
        # fault machinery: chaos is env-armed by default (inert when the
        # launcher sets no SPION_CHAOS_* vars); preemption flag set by the
        # SIGTERM handler (install_preemption_handler) and OR-reduced
        # across processes at each step boundary so the save runs in
        # lockstep
        self.chaos = chaos if chaos is not None else ChaosMonkey.from_env()
        self._preempted = False
        self.preempted = False          # observable: loop exited via preemption
        self.heartbeat = None
        if ckpt_dir:
            self.heartbeat = Heartbeat(
                os.path.join(ckpt_dir, f"hb_{runtime.process_index()}"),
                interval=heartbeat_interval)

        params = self.bundle.init(jax.random.key(seed))
        self.params = masters_of(params)
        self.opt = adamw_init(self.params)

        self._dense_step = jax.jit(make_train_step(
            cfg, spion=False, lr=lr, total_steps=total_steps), donate_argnums=(0, 1))
        # one jitted sparse step for the whole run: the step receives a
        # SparseAttentionExec whose static block/halo ride the pytree
        # aux_data, so a NEW plan (different halo after a phase transition
        # or a sparse-phase resume) retraces automatically — no caller-side
        # halo tracking or lazy step rebuilds (DESIGN.md §11)
        self._sparse_step = jax.jit(make_train_step(
            cfg, spion=True, lr=lr, total_steps=total_steps,
            sparse_kernel=sparse_kernel), donate_argnums=(0, 1))
        capture_fn = lambda p, b, f, blk: self.bundle.forward(
            p, b, capture={"filt": f, "block": blk})[1]["captured"]
        if mesh is not None and runtime.process_count() > 1:
            # the capture stats feed the HOST-side flood-fill: with the mesh
            # spanning processes the outputs must come back fully
            # replicated, or np.asarray on a partially-addressable global
            # array would throw on every process but 0
            self._capture = jax.jit(capture_fn, static_argnames=("blk",),
                                    out_shardings=NamedSharding(mesh, P()))
        else:
            self._capture = jax.jit(capture_fn, static_argnames=("blk",))
        self.supervisor = StepSupervisor(self._restore_latest)

    # -- multi-process plumbing ----------------------------------------------

    def install_preemption_handler(self):
        """SIGTERM -> finish the in-flight step, then save and exit cleanly
        (the fleet-wide OR in the loop makes every process save at the SAME
        step even when the signal lands on one host). Main thread only."""
        def _handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, _handler)

    def _device_batch(self, batch):
        """Host batch -> device. Multi-process: every process holds the same
        global batch; build committed global arrays sharded over the
        'pod'/'data' axes so each device keeps only its slice."""
        if self.mesh is not None and runtime.process_count() > 1:
            return runtime.make_global(
                self.mesh, batch, batch_pspecs(self.cfg, batch, self.mesh))
        return {k: jnp.asarray(v) for k, v in batch.items()}

    def _next_batch(self):
        b = self.data_fn(self.step) if self.data_fn else next(self.data)
        return self._device_batch(b)

    # -- checkpoint/restart --------------------------------------------------

    def _state_tree(self):
        return {"params": self.params, "opt": self.opt}

    def save(self):
        if self.ckpt:
            # plan tables go binary (extra_arrays) — the JSON extra keeps only
            # scalars, so a production-size SparsityPlan doesn't bloat meta.
            # In multi-process runs this is a collective (all-gather to host
            # on every process; process 0 writes) — every process calls it.
            arrays = self.spion_state.table_arrays()
            self.ckpt.save(
                self.step, self._state_tree(),
                extra={"spion": self.spion_state.to_py(include_tables=False),
                       "step": self.step},
                extra_arrays=None if arrays is None else
                {f"spion_{k}": v for k, v in arrays.items()})

    def _restore_shardings(self):
        """Shardings for the state tree on the CURRENT mesh — the elastic
        half of recovery: the checkpoint is mesh-agnostic (fully gathered),
        and restore re-shards it for however many processes/devices this
        incarnation of the job has."""
        if self.mesh is None:
            return None
        psh = param_shardings(self.mesh, self.params)
        rep = NamedSharding(self.mesh, P())
        return {"params": psh,
                "opt": {"mu": psh, "nu": psh, "count": rep}}

    def _restore_latest(self):
        if not self.ckpt:
            return
        tree, step, extra = self.ckpt.restore(
            target=self._state_tree(), shardings=self._restore_shardings())
        if tree is not None:
            self.params, self.opt = tree["params"], tree["opt"]
            self.step = extra.get("step", step or 0)
            if extra.get("spion"):
                arrays = {k[len("spion_"):]: v
                          for k, v in extra.get("_arrays", {}).items()
                          if k.startswith("spion_")} or None
                self.spion_state = SpionState.from_py(extra["spion"], arrays)
                # every process read the checkpoint independently; a torn
                # read or mixed-up dir on one host must fail loudly, not
                # train through a different pattern (DESIGN.md §12)
                self.spion_ctl.verify_plan_sync(self.spion_state)

    def maybe_resume(self):
        if self.ckpt and self.ckpt.latest_step() is not None:
            self._restore_latest()
            return True
        return False

    # -- steps ----------------------------------------------------------------

    def _one_step(self, batch):
        ex = self.spion_ctl.attention_exec(self.spion_state)
        if ex is not None:
            self.params, self.opt, metrics = self._sparse_step(
                self.params, self.opt, batch, jnp.int32(self.step), ex)
        else:
            self.params, self.opt, metrics = self._dense_step(
                self.params, self.opt, batch, jnp.int32(self.step))
        self.step += 1
        return metrics

    def _epoch_boundary(self, batch):
        """SPION capture + transition check on a capture batch. Pattern
        generation inside observe_epoch is single-controller: process 0
        flood-fills, everyone receives the broadcast plan (core/spion.py)."""
        cap = self.spion_ctl.capture_kwargs(self.spion_state)
        if cap is None:
            self.spion_state.epoch += 1
            return
        pc = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.dtype(self.cfg.dtype)) if x.ndim >= 2 and
            x.dtype == jnp.float32 else x, self.params)
        pooled, frob = self._capture(pc, batch, cap["filt"], cap["block"])
        self.spion_state = self.spion_ctl.observe_epoch(
            self.spion_state, np.asarray(pooled), np.asarray(frob))

    def _check_preempted(self) -> bool:
        """Fleet-wide preemption decision, same answer on every process at
        the same step (one tiny collective per step in multi-process runs)."""
        if runtime.process_count() > 1:
            return runtime.any_flag(self._preempted)
        return self._preempted

    def train(self, num_steps, *, ckpt_every=100, log_every=10, log=print):
        log0 = log if runtime.is_coordinator() else (lambda *a, **k: None)
        with mesh_context(self.mesh):
            t_total = time.time()
            losses = []
            target = self.step + num_steps
            while self.step < target:
                if self.chaos:
                    self.chaos.maybe_kill(self.step)
                if self._check_preempted():
                    self.preempted = True
                    self.save()
                    if self.ckpt:
                        self.ckpt.wait()
                    log0(f"preempted: saved step {self.step}, exiting")
                    return losses
                batch = self._next_batch()
                t0 = time.time()
                metrics = self.supervisor.run(self._one_step, batch)
                dt = time.time() - t0
                straggler = self.monitor.observe(dt)
                if self.heartbeat:
                    self.heartbeat.beat()
                losses.append(float(metrics["loss"]))
                if self.step % log_every == 0:
                    log0(f"step {self.step} loss {np.mean(losses[-log_every:]):.4f} "
                         f"phase {self.spion_state.phase} dt {dt*1e3:.0f}ms"
                         + (" [straggler]" if straggler else ""))
                if self.step % self.steps_per_epoch == 0:
                    self._epoch_boundary(batch)
                if ckpt_every and self.step % ckpt_every == 0:
                    self.save()
            self.save()
            if self.ckpt:
                self.ckpt.wait()
            log0(f"done: {num_steps} steps in {time.time()-t_total:.1f}s, "
                 f"final phase={self.spion_state.phase} density={self.spion_state.density}")
            return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="spion-lra")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--sparse-kernel", default=None,
                    choices=["auto", "jnp", "fused"],
                    help="sparse-phase attention impl (default: cfg.spion.kernel; "
                         "auto = fused Pallas kernel on TPU, jnp path elsewhere)")
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT",
                    help="jax.distributed coordinator (or env SPION_COORDINATOR); "
                         "with --num-processes/--process-id this process joins "
                         "a multi-host job")
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    args = ap.parse_args()
    distributed = runtime.initialize(args.coordinator, args.num_processes,
                                     args.process_id)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_distributed_mesh() if distributed else None
    tr = Trainer(cfg, seq_len=args.seq_len, batch=args.batch,
                 ckpt_dir=args.ckpt_dir, mesh=mesh,
                 sparse_kernel=args.sparse_kernel)
    tr.install_preemption_handler()
    tr.maybe_resume()
    tr.train(args.steps)


if __name__ == "__main__":
    main()
