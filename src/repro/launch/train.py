"""End-to-end training driver with the full production loop:

  data pipeline -> jitted train step (dense phase) -> SPION capture between
  epochs -> Frobenius transition -> pattern generation -> sparse phase ->
  checkpoints (atomic, async, keep-K) -> crash-restart supervisor ->
  straggler monitor.

CPU-runnable at reduced scale (examples/ wire it up); identical code paths
lower onto the production meshes (launch/dryrun.py proves compile).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core.spion import SpionController, SpionState
from repro.data.synthetic import lm_batch_iterator
from repro.distributed.fault import StepSupervisor, StragglerMonitor
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models.registry import build
from repro.optim import adamw_init

# XLA flags for real TPU runs (latency-hiding scheduler = compute/comm overlap)
TPU_XLA_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_megacore_fusion_allow_ags=true "
    "--xla_enable_async_collective_permute=true "
    "--xla_tpu_overlap_compute_collective_tc=true"
)


def masters_of(params):
    return jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.ndim >= 2 else x, params)


class Trainer:
    def __init__(self, cfg, *, seq_len, batch, lr=3e-4, total_steps=1000,
                 ckpt_dir=None, mesh=None, seed=0, steps_per_epoch=50,
                 data_iter=None, capture_batches=1, sparse_kernel=None):
        self.cfg = cfg
        self.bundle = build(cfg)
        self.mesh = mesh
        self.seq_len = seq_len
        self.steps_per_epoch = steps_per_epoch
        self.spion_ctl = SpionController(cfg.spion, causal=cfg.causal, seq_len=seq_len)
        self.spion_state = SpionState()
        self.monitor = StragglerMonitor()
        self.ckpt = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
        self.step = 0
        rng = np.random.default_rng(seed)
        self.data = data_iter if data_iter is not None else lm_batch_iterator(
            rng, batch=batch, seq_len=seq_len + 1, vocab=cfg.vocab_size)

        params = self.bundle.init(jax.random.key(seed))
        self.params = masters_of(params)
        self.opt = adamw_init(self.params)

        self._dense_step = jax.jit(make_train_step(
            cfg, spion=False, lr=lr, total_steps=total_steps), donate_argnums=(0, 1))
        # one jitted sparse step for the whole run: the step receives a
        # SparseAttentionExec whose static block/halo ride the pytree
        # aux_data, so a NEW plan (different halo after a phase transition
        # or a sparse-phase resume) retraces automatically — no caller-side
        # halo tracking or lazy step rebuilds (DESIGN.md §11)
        self._sparse_step = jax.jit(make_train_step(
            cfg, spion=True, lr=lr, total_steps=total_steps,
            sparse_kernel=sparse_kernel), donate_argnums=(0, 1))
        self._capture = jax.jit(
            lambda p, b, f, blk: self.bundle.forward(
                p, b, capture={"filt": f, "block": blk})[1]["captured"],
            static_argnames=("blk",))
        self.supervisor = StepSupervisor(self._restore_latest)

    # -- checkpoint/restart --------------------------------------------------

    def _state_tree(self):
        return {"params": self.params, "opt": self.opt}

    def save(self):
        if self.ckpt:
            # plan tables go binary (extra_arrays) — the JSON extra keeps only
            # scalars, so a production-size SparsityPlan doesn't bloat meta
            arrays = self.spion_state.table_arrays()
            self.ckpt.save(
                self.step, self._state_tree(),
                extra={"spion": self.spion_state.to_py(include_tables=False),
                       "step": self.step},
                extra_arrays=None if arrays is None else
                {f"spion_{k}": v for k, v in arrays.items()})

    def _restore_latest(self):
        if not self.ckpt:
            return
        tree, step, extra = self.ckpt.restore(target=self._state_tree())
        if tree is not None:
            self.params, self.opt = tree["params"], tree["opt"]
            self.step = extra.get("step", step or 0)
            if extra.get("spion"):
                arrays = {k[len("spion_"):]: v
                          for k, v in extra.get("_arrays", {}).items()
                          if k.startswith("spion_")} or None
                self.spion_state = SpionState.from_py(extra["spion"], arrays)

    def maybe_resume(self):
        if self.ckpt and self.ckpt.latest_step() is not None:
            self._restore_latest()
            return True
        return False

    # -- steps ----------------------------------------------------------------

    def _one_step(self, batch):
        ex = self.spion_ctl.attention_exec(self.spion_state)
        if ex is not None:
            self.params, self.opt, metrics = self._sparse_step(
                self.params, self.opt, batch, jnp.int32(self.step), ex)
        else:
            self.params, self.opt, metrics = self._dense_step(
                self.params, self.opt, batch, jnp.int32(self.step))
        self.step += 1
        return metrics

    def _epoch_boundary(self, batch):
        """SPION capture + transition check on a capture batch."""
        cap = self.spion_ctl.capture_kwargs(self.spion_state)
        if cap is None:
            self.spion_state.epoch += 1
            return
        pc = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.dtype(self.cfg.dtype)) if x.ndim >= 2 and
            x.dtype == jnp.float32 else x, self.params)
        pooled, frob = self._capture(pc, batch, cap["filt"], cap["block"])
        self.spion_state = self.spion_ctl.observe_epoch(
            self.spion_state, np.asarray(pooled), np.asarray(frob))

    def train(self, num_steps, *, ckpt_every=100, log_every=10, log=print):
        with mesh_context(self.mesh):
            t_total = time.time()
            losses = []
            target = self.step + num_steps
            while self.step < target:
                batch = next(self.data)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                t0 = time.time()
                metrics = self.supervisor.run(self._one_step, batch)
                dt = time.time() - t0
                straggler = self.monitor.observe(dt)
                losses.append(float(metrics["loss"]))
                if self.step % log_every == 0:
                    log(f"step {self.step} loss {np.mean(losses[-log_every:]):.4f} "
                        f"phase {self.spion_state.phase} dt {dt*1e3:.0f}ms"
                        + (" [straggler]" if straggler else ""))
                if self.step % self.steps_per_epoch == 0:
                    self._epoch_boundary(batch)
                if ckpt_every and self.step % ckpt_every == 0:
                    self.save()
            self.save()
            if self.ckpt:
                self.ckpt.wait()
            log(f"done: {num_steps} steps in {time.time()-t_total:.1f}s, "
                f"final phase={self.spion_state.phase} density={self.spion_state.density}")
            return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="spion-lra")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--sparse-kernel", default=None,
                    choices=["auto", "jnp", "fused"],
                    help="sparse-phase attention impl (default: cfg.spion.kernel; "
                         "auto = fused Pallas kernel on TPU, jnp path elsewhere)")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tr = Trainer(cfg, seq_len=args.seq_len, batch=args.batch,
                 ckpt_dir=args.ckpt_dir, mesh=None,
                 sparse_kernel=args.sparse_kernel)
    tr.maybe_resume()
    tr.train(args.steps)


if __name__ == "__main__":
    main()
