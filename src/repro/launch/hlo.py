"""Post-SPMD HLO analysis: collective bytes, op census, roofline terms.

collective_bytes sums the *operand* sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute in the partitioned module
(cost_analysis does not report collectives). A symbol table of instruction
result shapes resolves operand names; tuples are expanded.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w\-]+)\(")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Sum byte sizes of every 'dtype[d0,d1]' occurrence in type_str
    (handles tuple types '(f32[2,3], bf16[4])')."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Returns {'total_bytes', 'by_kind': {kind: bytes}, 'count': {kind: n}}.

    Uses each collective's operand sizes where resolvable (symbol table),
    else the result size. `-start` variants are folded into their base kind
    ('-done' ops are skipped to avoid double counting).
    """
    shapes: dict = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if m:
            shapes[m.group(1)] = m.group(2)

    by_kind: dict = defaultdict(int)
    count: dict = defaultdict(int)
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        name, result_type, op = m.group(1), m.group(2), m.group(3)
        base = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                base = c
                break
        if base is None:
            continue
        if op.endswith("-done"):
            continue
        # operand list: text inside the outermost parens after the op name
        try:
            args_str = ln.split(op + "(", 1)[1]
            depth, end = 1, 0
            for i, ch in enumerate(args_str):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operand_names = [a.strip().lstrip("%") for a in args_str[:end].split(",")]
            operand_names = [a.split(" ")[-1].lstrip("%") for a in operand_names if a]
            b = 0
            for on in operand_names:
                if on in shapes:
                    b += _shape_bytes(shapes[on])
            if b == 0:
                b = _shape_bytes(result_type)
        except Exception:
            b = _shape_bytes(result_type)
        by_kind[base] += b
        count[base] += 1
    return {
        "total_bytes": int(sum(by_kind.values())),
        "by_kind": {k: int(v) for k, v in by_kind.items()},
        "count": {k: int(v) for k, v in count.items()},
    }


def op_census(hlo_text: str, ops=("fusion", "all-reduce", "all-gather",
                                  "reduce-scatter", "all-to-all",
                                  "collective-permute", "convolution", "dot",
                                  "custom-call", "while", "transpose",
                                  "reshape", "copy")) -> dict:
    out = {}
    for op in ops:
        out[op] = len(re.findall(rf"=\s*(?:\(?[^=]*?\)?)\s*{re.escape(op)}\(", hlo_text))
    return out


def roofline_terms(flops, hbm_bytes, coll_bytes, chips, *, peak_flops, hbm_bw,
                   link_bw):
    """The three §Roofline terms, in seconds (whole-mesh workload)."""
    return {
        "t_compute": flops / (chips * peak_flops),
        "t_memory": hbm_bytes / (chips * hbm_bw),
        "t_collective": coll_bytes / (chips * link_bw),
    }
