"""Step builders shared by dryrun / train / serve: jitted train_step,
prefill_step and serve_step with full in/out shardings for a target mesh.
"""
from __future__ import annotations

import functools
from dataclasses import replace as dataclasses_replace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core.attention_exec import SparseAttentionExec
from repro.core.sparse_attention import PLAN_TABLE_KEYS
from repro.distributed.sharding import (data_axes, param_pspecs, sanitize_spec,
                                         zero1_pspecs)
from repro.models.registry import build, cache_specs, input_specs
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedule import cosine_schedule


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def batch_pspecs(cfg: ModelConfig, batch, mesh: Mesh):
    """Shard batch leading (batch) dim over the data axes; on a
    sequence-parallel mesh the (B, S, ...) token dim additionally shards
    over 'seq' (GSPMD reshards as needed up to the kernel shard_map
    boundary, which consumes exactly this layout)."""
    daxes = data_axes(mesh)
    seq_ax = "seq" if mesh.shape.get("seq", 1) > 1 else None

    def one(x):
        if x.ndim == 0:
            return P()
        rest = [seq_ax] + [None] * (x.ndim - 2) if x.ndim >= 2 else []
        return sanitize_spec(mesh, P(daxes, *rest), x.shape)
    return jax.tree_util.tree_map(one, batch)


def cache_pspecs(cfg: ModelConfig, cache, mesh: Mesh, batch_size: int):
    """KV caches: (L, B, S, KV, hd) — shard B over data when it covers the
    axis, else shard S (sequence parallelism for long_500k batch=1).
    SSM states (L, B, H, ...): shard H over model; B over data when possible."""
    daxes = data_axes(mesh)
    ndata = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    batch_big = batch_size >= ndata

    model_size = mesh.shape.get("model", 1)

    def one(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v", "ck", "cv"):           # (L,B,S,KV,hd)
            KV, hd = x.shape[3], x.shape[4]
            # shard KV heads over model when divisible, else head_dim
            # (uneven KV heads would silently drop to replicated: 8-16x cache)
            kv_ax, hd_ax = ("model", None) if KV % model_size == 0 else \
                (None, "model") if hd % model_size == 0 else (None, None)
            if batch_big:
                return P(None, daxes, None, kv_ax, hd_ax)
            return P(None, None, daxes, kv_ax, hd_ax)
        if name == "S":                               # rwkv (L,B,H,hd,hd)
            return P(None, daxes if batch_big else None, "model", None, None)
        if name == "ssm":                             # mamba (L,B,H,N,P)
            return P(None, daxes if batch_big else None, "model", None, None)
        if name == "conv":                            # (L,B,W-1,convdim)
            return P(None, daxes if batch_big else None, None, "model")
        if name in ("tm_prev", "cm_prev"):            # (L,B,d)
            return P(None, daxes if batch_big else None, None)
        return P()

    def sanitized(path, x):
        return sanitize_spec(mesh, one(path, x), x.shape)
    return jax.tree_util.tree_map_with_path(sanitized, cache)


def spion_dryrun_tables(cfg: ModelConfig, seq_len: int, layers: Optional[int] = None,
                        max_extent: Optional[int] = None):
    """Deterministic SPION-shaped pattern (diag band + verticals) at the
    configured alpha density — the sparse-phase stand-in for dry-runs.
    Tables are tiny ((Ly, nrb, K) int32) and enter the step as inputs.

    Emits the full SparsityPlan payload — forward tables PLUS the host-built
    transposed tables (row_idx (Ly, nrb, KT*), nvalid_t (Ly, nrb)), the
    static width 'kt_star' and the static 'halo' column-extent pair — so
    dryrun/HLO checks exercise the exact step signature (and catch
    plan-shape bugs) before a real run.

    `max_extent` clips the off-diagonal verticals to the band
    [r - max_extent, r + max_extent]: the default global verticals make the
    pattern's column extent ~nrb (a seq-parallel mesh then falls back to
    batch/KV sharding by design); a bounded band stands in for the
    near-diagonal flood-fill patterns the halo exchange targets."""
    from repro.core.sparse_attention import build_sparsity_plan
    cols, nval, blk, nrb = _dryrun_pattern(cfg, seq_len, layers, max_extent)
    plan = build_sparsity_plan(cols, nval, blk, ncb=nrb)
    return dict(plan.tables, kt_star=plan.kt_star,
                halo=plan.stats["halo"])


def spion_dryrun_halo(cfg: ModelConfig, seq_len: int,
                      layers: Optional[int] = None,
                      max_extent: Optional[int] = None):
    """Just the [left, right] halo extents of the dry-run pattern — the
    cheap forward-table scan (core.sparse_attention.pattern_col_extents),
    WITHOUT the host transpose spion_dryrun_tables pays. For dry-run cells
    that only record the seq-sharding resolution."""
    from repro.core.sparse_attention import pattern_col_extents
    cols, nval, _, nrb = _dryrun_pattern(cfg, seq_len, layers, max_extent)
    ext_l, ext_r = pattern_col_extents(cols, nval, ncb=nrb)
    return [int(ext_l.max()), int(ext_r.max())]


def _dryrun_pattern(cfg: ModelConfig, seq_len: int, layers, max_extent):
    """The deterministic dry-run pattern's forward tables (host numpy)."""
    import numpy as np
    sp = cfg.spion
    blk = sp.block_size
    nrb = max(seq_len // blk, 1)
    Ly = layers if layers is not None else cfg.num_layers
    keep = max(1.0 - sp.alpha_quantile, 1.0 / nrb)
    K = max(int(np.ceil(nrb * keep)) + 1, 2)
    K = min(K, nrb)
    rng = np.random.default_rng(0)
    cols = np.zeros((Ly, nrb, K), np.int32)
    nval = np.full((Ly, nrb), K, np.int32)
    for l in range(Ly):
        for r in range(nrb):
            c = {r}  # forced diagonal
            c.add(max(r - 1, 0))                       # band
            verts = rng.integers(0, nrb, size=K)
            for v0 in verts:
                if len(c) >= K:
                    break
                if max_extent is not None:
                    v0 = int(np.clip(v0, r - max_extent, r + max_extent))
                    v0 = int(np.clip(v0, 0, nrb - 1))
                    c.add(min(v0, r) if cfg.causal else v0)
                else:
                    c.add(int(v0 if not cfg.causal else min(v0 % (r + 1), r)))
            cs = sorted(c)[:K]
            cols[l, r, : len(cs)] = cs
            nval[l, r] = len(cs)
            if len(cs) < K:
                cols[l, r, len(cs):] = cs[-1]          # clamped padding
    return cols, nval, blk, nrb


def causal_band_tables(layers: int, nrb: int, width: Optional[int] = None):
    """Stacked causal stand-in forward tables (host numpy) for serving
    demos, benches and tests: each row-block lists its last `width` column
    blocks (width=None -> all of them: full causal coverage, the
    sparse-equals-dense case). Clamped padding past the valid prefix,
    matching the bcsr_from_blockmask convention. ONE builder on purpose —
    bench/example/test stand-ins must not drift from each other."""
    import numpy as np
    K = nrb if width is None else width
    col = np.zeros((layers, nrb, K), np.int32)
    nval = np.zeros((layers, nrb), np.int32)
    for r in range(nrb):
        lo = 0 if width is None else max(r - width + 1, 0)
        cs = list(range(lo, r + 1))
        col[:, r, : len(cs)] = cs
        col[:, r, len(cs):] = cs[-1]
        nval[:, r] = len(cs)
    return {"col_idx": col, "nvalid": nval}


def spion_table_pspecs(tables):
    """Replicated specs for every array leaf; None for static ints
    (block / kt_star) — the plan tables are kilobytes, broadcast whole.

    Replication is load-bearing, not just cheap: under a multi-device mesh
    the fused kernel runs inside a shard_map whose table in_specs are P()
    (kernels/sharded.py) — the tables index the full, unsharded sequence
    axis, so every shard needs the whole table. Feeding them in already
    replicated means the shard_map boundary is a no-op instead of an
    all-gather.

    Accepts the dict payload or a SparseAttentionExec (tree_map'd leaf-wise:
    its statics live in aux_data, so every leaf is an array)."""
    if isinstance(tables, SparseAttentionExec):
        return jax.tree_util.tree_map(lambda _: P(), tables)
    return {k: (P() if hasattr(v, "shape") else None)
            for k, v in tables.items()}


def _coerce_step_tables(tables, *, block, halo, phase, kernel_config=None):
    """Normalise a step's sparse-tables argument to a SparseAttentionExec.

    An exec passes through untouched (it carries its own static metadata as
    pytree aux, so it crosses jit boundaries intact — including the
    autotuned kernel_config resolved when it was built OUTSIDE jit). The
    legacy dict payload is rebuilt with the STATIC block/halo/kernel_config
    closed over at step-build time — its own int leaves would be tracers
    under jit, and the autotune-cache lookup needs concrete tables, so this
    under-jit construction never consults the cache itself — and filtered
    to the PLAN_TABLE_KEYS arrays (dropping static scalars like kt_star).
    Callers who want tuned dict payloads pass `kernel_config` to the step
    maker (or, better, hand the step an exec)."""
    if tables is None:
        return None
    if isinstance(tables, SparseAttentionExec):
        return tables
    arrays = {k: tables[k] for k in PLAN_TABLE_KEYS if k in tables}
    return SparseAttentionExec(arrays, block=block, halo=halo, phase=phase,
                               kernel_config=kernel_config)


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, *, spion=False, seq_len=None, lr=3e-4,
                    total_steps=10_000, n_micro=1, block=None,
                    sparse_kernel=None, halo=None, kernel_config=None):
    """Returns f(params_f32, opt_state, batch, step[, tables]) ->
    (params, opt_state, metrics). `spion` adds a sparse-tables argument:
    either a SparseAttentionExec (preferred — its static block/halo ride
    the pytree aux_data, so a changed plan retraces with no caller
    bookkeeping; SpionController.attention_exec builds it) or the legacy
    dict payload ({'col_idx','nvalid'} arrays, optionally a SparsityPlan's
    transposed {'row_idx','nvalid_t'} — then the fused sparse backward runs
    its dK/dV grid at the plan width KT* with no under-jit transpose; the
    block size is STATIC via `block` / cfg.spion.block_size — an int leaf
    would turn into a tracer under jit).
    n_micro > 1 scans microbatches with gradient accumulation (activation
    memory scales ~1/n_micro; the standard large-scale fit knob).

    `sparse_kernel` overrides cfg.spion.kernel ("auto" | "jnp" | "fused"):
    the sparse phase differentiates end-to-end through either path — the
    fused Pallas kernel carries its own sparse backward (custom VJP). The
    dispatch is mesh-aware: traced under an active multi-device mesh
    (mesh_context), "auto"/"fused" route through the shard_map wrapper so
    the kernel and its backward stay sharded on pods
    (models.attention.resolve_sparse_kernel).

    `halo` is the SparsityPlan's STATIC (left, right) column-extent pair
    (plan stats["halo"]); like `block` it is closed over at build time — an
    int leaf in the tables arg would turn into a tracer under jit. It
    unlocks 'seq'-axis sharding of the fused kernel when the mesh has one
    (DESIGN.md §10); leaving it None just keeps the sequence unsharded.

    `kernel_config` is a kernels.dispatch.KernelConfig for dict-payload
    callers (static, closed over like block/halo). Exec arguments carry
    their own — resolved from the autotune cache at construction."""
    if sparse_kernel is not None:
        cfg = cfg.replace(spion=dataclasses_replace(cfg.spion,
                                                    kernel=sparse_kernel))
    bundle = build(cfg)
    compute_dtype = jnp.dtype(cfg.dtype)
    static_block = block or cfg.spion.block_size
    static_halo = None if halo is None else (int(halo[0]), int(halo[1]))

    def step_fn(params, opt_state, batch, step, tables=None):
        # single owner of the sparse-attention state: dict payloads become
        # a SparseAttentionExec with the STATIC block/halo closed over at
        # build time; an exec argument (launch/train.Trainer) passes
        # through with its own statics in the pytree aux — so a new plan's
        # halo retraces the step with no caller-side rebuild tracking
        tables = _coerce_step_tables(tables, block=static_block,
                                     halo=static_halo, phase="train",
                                     kernel_config=kernel_config)

        def cast(p):
            return jax.tree_util.tree_map(
                lambda x: x.astype(compute_dtype)
                if x.dtype == jnp.float32 and x.ndim >= 2 else x, p)

        def loss_fn(p, mb):
            return bundle.loss(cast(p), mb, spion=tables)

        if n_micro > 1:
            def split(x):
                y = x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
                return constrain_micro(y)
            mbs = jax.tree_util.tree_map(split, batch)

            def body(carry, mb):
                acc_loss, acc_g = carry
                (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                acc_g = jax.tree_util.tree_map(jnp.add, acc_g, g)
                return (acc_loss + l, acc_g), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), mbs)
            loss = loss / n_micro
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
        else:
            (loss, _aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr_t = cosine_schedule(step, peak=lr, warmup_steps=200, total_steps=total_steps)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr_t)
        metrics = {"loss": loss.astype(jnp.float32), "gnorm": gnorm,
                   "lr": lr_t if hasattr(lr_t, "dtype") else jnp.float32(lr_t)}
        return params, opt_state, metrics

    def constrain_micro(y):
        from repro.distributed.sharding import constrain
        spec = ["batch"] + [None] * (y.ndim - 2)
        return constrain(y, None, *spec)

    if spion:
        def with_tables(params, opt_state, batch, step, tables):
            return step_fn(params, opt_state, batch, step, tables)
        return with_tables
    return functools.partial(step_fn, tables=None)


def make_prefill_step(cfg: ModelConfig, *, spion=False, block=None,
                      halo=None, with_cache=False, kernel_config=None):
    """Prefill step: logits over the full prompt. `with_cache=True` builds
    the FUSED serving prefill instead — (params, batch[, tables]) ->
    (logits, ks, vs) with ks/vs the per-layer RoPE'd K/V stacked
    (L, B, S, KV, hd), ready for direct insertion into decode-cache slots
    (launch/serve.ServeEngine) — no token-by-token teacher forcing and no
    padded-prompt cache pollution. Families without a plain KV cache have
    no fused prefill (bundle.prefill_kv is None) and raise here."""
    bundle = build(cfg)
    static_block = block or cfg.spion.block_size
    static_halo = None if halo is None else (int(halo[0]), int(halo[1]))
    if with_cache and bundle.prefill_kv is None:
        raise NotImplementedError(
            f"make_prefill_step(with_cache=True): family {cfg.family!r} has "
            f"no fused KV prefill; serve it via stepwise prefill instead")

    def prefill(params, batch, tables=None):
        ex = _coerce_step_tables(tables, block=static_block,
                                 halo=static_halo, phase="prefill",
                                 kernel_config=kernel_config)
        if with_cache:
            return bundle.prefill_kv(params, batch, spion=ex)
        logits, _ = bundle.forward(params, batch, spion=ex)
        return logits

    if spion:
        return prefill
    return functools.partial(prefill, tables=None)


def make_serve_step(cfg: ModelConfig, *, spion=False, block=None, halo=None,
                    kernel_config=None):
    """Decode step: (params, cache, tokens, pos[, tables]) -> (logits,
    cache). `pos` may be a scalar or per-row (B,) vector; with `spion` the
    attention families decode sparsely over the pattern-listed cache blocks
    (tables dict or SparseAttentionExec, as in make_train_step). The cache
    may be the family's contiguous form or its paged form (a
    core.kv_pool.PagedKVCache, standalone or under a "kv" key) — the
    decode_step dispatches on the cache type.

    spion=True on a family without an attention KV cache (rwkv/ssm) raises
    here, at step construction — the registry-level capability flag
    (bundle.supports_sparse_decode), not a trace-time surprise deep in the
    layer scan."""
    bundle = build(cfg)
    if spion and not bundle.supports_sparse_decode:
        raise NotImplementedError(
            f"make_serve_step(spion=True): family {cfg.family!r} (arch "
            f"{cfg.name!r}) keeps recurrent state, not an attention KV "
            f"cache — registry supports_sparse_decode is False for it. "
            f"Build the step with spion=False and serve densely.")
    static_block = block or cfg.spion.block_size
    static_halo = None if halo is None else (int(halo[0]), int(halo[1]))

    def serve_step(params, cache, tokens, pos, tables=None):
        ex = _coerce_step_tables(tables, block=static_block,
                                 halo=static_halo, phase="decode",
                                 kernel_config=kernel_config)
        return bundle.decode_step(params, cache, tokens, pos, spion=ex)

    if spion:
        return serve_step
    return functools.partial(serve_step, tables=None)


# ---------------------------------------------------------------------------
# shardings for the step signatures
# ---------------------------------------------------------------------------

def train_shardings(cfg, mesh, params_tree, opt_tree, batch_tree, *, zero1=True):
    psp = param_pspecs(params_tree)
    osp = {
        "mu": zero1_pspecs(params_tree, mesh) if zero1 else psp,
        "nu": zero1_pspecs(params_tree, mesh) if zero1 else psp,
        "count": P(),
    }
    bsp = batch_pspecs(cfg, batch_tree, mesh)
    to_ns = lambda t: jax.tree_util.tree_map(lambda s: _ns(mesh, s), t,
                                             is_leaf=lambda x: isinstance(x, P))
    return to_ns(psp), to_ns(osp), to_ns(bsp)
