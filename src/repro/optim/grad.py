"""Gradient utilities: global-norm clipping, microbatch accumulation, and
int8 gradient compression for the DP all-reduce (a distributed-optimization
trick: 4x smaller cross-pod reduce traffic; error feedback keeps it unbiased
in the long run).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (n + 1e-6))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads), n


def accumulate_microbatches(loss_fn, params, batches, n_micro):
    """lax.scan over microbatches; returns (mean_loss, mean_grads, aux_last).
    `batches` leaves have leading dim n_micro."""
    def body(carry, mb):
        acc_loss, acc_g = carry
        (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc_g = jax.tree_util.tree_map(lambda a, b: a + b, acc_g, g)
        return (acc_loss + loss, acc_g), aux

    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, g), aux = jax.lax.scan(body, (jnp.zeros(()), zeros), batches)
    scale = 1.0 / n_micro
    return loss * scale, jax.tree_util.tree_map(lambda x: x * scale, g), aux


# ---------------------------------------------------------------------------
# int8 compression (for shard_map DP all-reduce and checkpoint shrink)
# ---------------------------------------------------------------------------

def quantize_int8(x):
    """Symmetric per-tensor int8 quantisation: (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(tree, axis_name):
    """Quantise -> psum(int32) -> dequantise with psum'd scales.

    Each participant contributes its int8 payload; scales are averaged.
    Used inside shard_map over the DP axes (distributed/collectives.py)."""
    def one(x):
        q, s = quantize_int8(x)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        s_mean = jax.lax.pmean(s, axis_name)
        return (total.astype(jnp.float32) * s_mean).astype(x.dtype)
    return jax.tree_util.tree_map(one, tree)
