from repro.optim.adamw import adamw_init, adamw_update  # noqa: F401
from repro.optim.schedule import cosine_schedule, linear_warmup  # noqa: F401
from repro.optim.grad import clip_by_global_norm, global_norm  # noqa: F401
