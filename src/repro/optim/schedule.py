"""LR schedules (pure functions of step)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, *, peak, warmup_steps):
    return peak * jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))


def cosine_schedule(step, *, peak, warmup_steps, total_steps, floor=0.1):
    warm = linear_warmup(step, peak=peak, warmup_steps=warmup_steps)
    frac = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup_steps, warm, peak * cos)
