"""AdamW in pure JAX. Optimizer state is a pytree mirroring params; with
ZeRO-1 the state is sharded over the data axes (distributed/sharding.py
zero1_pspecs) — GSPMD inserts the gather on use.

Master weights are fp32 regardless of the (bf16) compute params: `params`
passed here are the fp32 masters; callers cast to cfg.dtype for the forward.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    c1 = 1.0 - b1 ** cf
    c2 = 1.0 - b2 ** cf

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        step = (mu / c1) / (jnp.sqrt(nu / c2) + eps)
        decay = weight_decay if p.ndim >= 2 else 0.0  # no decay on norms/bias
        newp = p.astype(jnp.float32) - lr * (step + decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), mu, nu

    flat = jax.tree_util.tree_map(upd, params, grads, state["mu"], state["nu"],
                                  is_leaf=lambda x: isinstance(x, jax.Array))
    new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "count": count}
