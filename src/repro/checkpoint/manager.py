"""Checkpointing: atomic, mesh-agnostic, async-capable.

Format: one directory per step containing
  - arrays.npz       every pytree leaf, fully replicated (gathered) view
  - meta.msgpack     treedef, step, extra host state (SPION phase, rng, ...)
  - extra_arrays.npz optional named numpy arrays outside the pytree (the
                     SPION SparsityPlan tables — int32 arrays that would
                     balloon the JSON `extra` at production sequence
                     lengths); restore returns them under extra["_arrays"]
  - DONE             commit marker (atomic rename makes the step visible)

Mesh-agnostic restore: leaves are saved unsharded, so a checkpoint taken on
256 chips restores onto 512 (elastic re-scale) — the caller re-applies its
own shardings via device_put. Async save: serialisation happens on a
background thread after jax.device_get (the step loop is blocked only for
the host transfer).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Optional

import jax
import msgpack
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, str(treedef)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             extra_arrays: Optional[dict] = None):
        """Gather to host, then (a)synchronously serialise + commit.
        `extra_arrays` ({name: array}) are persisted binary alongside the
        pytree — phase state like the SPION SparsityPlan tables rides here
        instead of being JSON-encoded into `extra`."""
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)
        if extra_arrays is not None:
            extra_arrays = {k: np.asarray(jax.device_get(v))
                            for k, v in extra_arrays.items()}
        if self._thread is not None:
            self._thread.join()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, extra or {},
                                          extra_arrays), daemon=True)
            self._thread.start()
        else:
            self._write(step, host_tree, extra or {}, extra_arrays)

    def _write(self, step: int, host_tree, extra: dict,
               extra_arrays: Optional[dict] = None):
        tmp = os.path.join(self.dir, f".tmp_step_{step:09d}")
        final = os.path.join(self.dir, f"step_{step:09d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = _flatten(host_tree)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": l for i, l in enumerate(leaves)})
        if extra_arrays:
            np.savez(os.path.join(tmp, "extra_arrays.npz"), **extra_arrays)
        with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
            f.write(msgpack.packb({"step": step, "treedef": treedef,
                                   "extra": json.dumps(extra)}))
        with open(os.path.join(tmp, "DONE"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    # -- restore -----------------------------------------------------------

    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and \
                    os.path.exists(os.path.join(self.dir, name, "DONE")):
                out.append(int(name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, target: Any = None,
                shardings: Any = None):
        """Returns (tree, step, extra). `target` supplies the treedef;
        `shardings` (optional pytree of NamedSharding) re-shards on load.
        Arrays saved via `extra_arrays` come back under extra["_arrays"]
        ({name: np.ndarray})."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None, None
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "meta.msgpack"), "rb") as f:
            meta = msgpack.unpackb(f.read())
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves = [data[f"leaf_{i}"] for i in range(len(data.files))]
        if target is not None:
            treedef = jax.tree_util.tree_structure(target)
            tree = jax.tree_util.tree_unflatten(treedef, leaves)
        else:
            raise ValueError("restore requires a `target` pytree for the treedef")
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        extra = json.loads(meta["extra"]) if meta.get("extra") else {}
        xa_path = os.path.join(path, "extra_arrays.npz")
        if os.path.exists(xa_path):
            with np.load(xa_path) as xa:
                extra["_arrays"] = {k: xa[k] for k in xa.files}
        return tree, meta["step"], extra
