"""Checkpointing: atomic, mesh-agnostic, async-capable, multi-process-aware.

Format: one directory per step containing
  - arrays.npz       every pytree leaf, fully replicated (gathered) view
  - meta.msgpack     treedef, step, extra host state (SPION phase, rng, ...)
  - extra_arrays.npz optional named numpy arrays outside the pytree (the
                     SPION SparsityPlan tables — int32 arrays that would
                     balloon the JSON `extra` at production sequence
                     lengths); restore returns them under extra["_arrays"]
  - DONE             commit marker (atomic rename makes the step visible)

Mesh-agnostic restore: leaves are saved unsharded, so a checkpoint taken on
256 chips restores onto 512 (elastic re-scale) — pass `shardings` built for
the *current* mesh and restore re-shards each leaf for it, however many
processes the new mesh spans. Async save: serialisation happens on a
background thread after the host gather (the step loop is blocked only for
the device->host transfer); a failed background write is surfaced on the
next save()/wait() instead of dying silently in the daemon thread.

Multi-process protocol (process-0-writes / all-read, DESIGN.md §12): every
process calls save()/wait()/restore() at the same step — the host gather is
a device collective all processes join — but only process 0 serialises and
commits. The cross-process *commit barrier* runs in wait() (main thread:
collectives must never interleave with training-step collectives from a
background thread), so after wait() returns, every process agrees the step
is committed and readable — restore()/latest_step() wait() first and
therefore never observe a half-written step or an in-flight async save.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import msgpack
import numpy as np

from repro.distributed import runtime


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, str(treedef)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True,
                 multiprocess: Optional[bool] = None):
        """`multiprocess=None` resolves lazily from jax.process_count() at
        the first collective call, so constructing a manager never touches
        the backend (dry-runs construct one before devices exist)."""
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._multiprocess = multiprocess
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._pending_commit = False
        self._pins: set = set()
        os.makedirs(directory, exist_ok=True)

    # -- multi-process roles ----------------------------------------------

    @property
    def multiprocess(self) -> bool:
        if self._multiprocess is None:
            self._multiprocess = jax.process_count() > 1
        return self._multiprocess

    @property
    def is_writer(self) -> bool:
        return not self.multiprocess or runtime.is_coordinator()

    # -- save ------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             extra_arrays: Optional[dict] = None):
        """Gather to host, then (a)synchronously serialise + commit.
        `extra_arrays` ({name: array}) are persisted binary alongside the
        pytree — phase state like the SPION SparsityPlan tables rides here
        instead of being JSON-encoded into `extra`. In a multi-process job
        this is a collective: every process must call it at the same step
        (the gather all-gathers process-spanning shards; process 0 writes)."""
        self.wait()  # join + surface any previous async write, then barrier
        if self.multiprocess:
            host_tree = runtime.fully_replicated_host(tree)
            if extra_arrays is not None:
                extra_arrays = runtime.fully_replicated_host(extra_arrays)
        else:
            host_tree = jax.tree_util.tree_map(
                lambda x: np.asarray(jax.device_get(x)), tree)
            if extra_arrays is not None:
                extra_arrays = {k: np.asarray(jax.device_get(v))
                                for k, v in extra_arrays.items()}
        if self.is_writer:
            if self.async_save:
                self._thread = threading.Thread(
                    target=self._write_guarded,
                    args=(step, host_tree, extra or {}, extra_arrays),
                    daemon=True)
                self._thread.start()
            else:
                self._write(step, host_tree, extra or {}, extra_arrays)
        # the commit is acknowledged fleet-wide at the next wait(): a
        # barrier here would block the step loop on the async write
        self._pending_commit = self.multiprocess

    def _write_guarded(self, *args):
        try:
            self._write(*args)
        except BaseException as e:  # noqa: BLE001 - surfaced on next save/wait
            self._error = e

    def _write(self, step: int, host_tree, extra: dict,
               extra_arrays: Optional[dict] = None):
        self._reap_orphans(keep_step=step)
        tmp = os.path.join(self.dir, f".tmp_step_{step:09d}")
        final = os.path.join(self.dir, f"step_{step:09d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = _flatten(host_tree)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": l for i, l in enumerate(leaves)})
        if extra_arrays:
            np.savez(os.path.join(tmp, "extra_arrays.npz"), **extra_arrays)
        with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
            f.write(msgpack.packb({"step": step, "treedef": treedef,
                                   "extra": json.dumps(extra)}))
        with open(os.path.join(tmp, "DONE"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def _reap_orphans(self, keep_step: Optional[int] = None):
        """Remove `.tmp_step_*` debris a crash-mid-save left behind (the
        arrays may exist but without the DONE+rename commit they are
        invisible to all_steps — and unreclaimed, they leak a full
        checkpoint of disk per crash). Pinned steps are exempt, like in
        `_gc`: a rollback target must never be touched by cleanup."""
        keep = None if keep_step is None else f".tmp_step_{keep_step:09d}"
        for name in os.listdir(self.dir):
            if name.startswith(".tmp_step_") and name != keep:
                try:
                    if int(name.split("_")[-1]) in self._pins:
                        continue
                except ValueError:
                    pass
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    def wait(self):
        """Block until any in-flight async save is durably committed; raise
        if the background write failed. Multi-process: also the commit
        barrier — every process must call (the Trainer's loop does so
        symmetrically via save()/restore()/latest_step())."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("checkpoint background write failed") from err
        if self._pending_commit:
            self._pending_commit = False
            runtime.barrier("ckpt_commit")

    def _gc(self):
        steps = self.all_steps(_wait=False)
        for s in steps[: -self.keep] if self.keep else []:
            if s in self._pins:
                continue  # a rollback target outlives the keep window
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    # -- divergence rollback support ---------------------------------------

    def pin(self, step: int):
        """Exempt `step` from `_gc`/`_reap_orphans` until unpinned — the
        divergence sentinel pins the last *good* checkpoint so the rollback
        target can never age out of the keep window while training runs
        past it. Pins are per-process in-memory state (each incarnation
        re-pins the step it restores), read from the async-writer thread;
        set mutation under the GIL is safe there."""
        self._pins.add(int(step))

    def unpin(self, step: int):
        self._pins.discard(int(step))

    def pinned(self):
        return sorted(self._pins)

    def quarantine_after(self, step: int):
        """Move every committed checkpoint with step > `step` aside
        (``step_X`` -> ``quarantined_step_X``): checkpoints saved after a
        divergence point hold poisoned optimizer state, and a later
        restore()/latest_step() must never pick one. Renamed dirs keep
        their payload for forensics but are invisible to `all_steps` (the
        ``step_`` prefix match). Multi-process: a collective like save —
        every process calls it; the writer renames; the trailing barrier
        guarantees no process restores a half-quarantined directory
        listing."""
        self.wait()
        if self.is_writer:
            for s in self.all_steps(_wait=False):
                if s <= step:
                    continue
                src = os.path.join(self.dir, f"step_{s:09d}")
                dst = os.path.join(self.dir, f"quarantined_step_{s:09d}")
                if os.path.exists(dst):
                    shutil.rmtree(dst)
                os.rename(src, dst)
        if self.multiprocess:
            runtime.barrier("ckpt_quarantine")

    # -- restore -----------------------------------------------------------

    def all_steps(self, _wait: bool = True):
        if _wait:
            self.wait()
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and \
                    os.path.exists(os.path.join(self.dir, name, "DONE")):
                out.append(int(name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, target: Any = None,
                shardings: Any = None):
        """Returns (tree, step, extra). `target` supplies the treedef;
        `shardings` (optional pytree of NamedSharding) re-shards on load —
        built against the *current* mesh, so a checkpoint taken on one
        process/host count restores onto another (each leaf is materialised
        shard-by-shard via make_array_from_callback, which is correct
        whether or not the sharding spans processes). Arrays saved via
        `extra_arrays` come back under extra["_arrays"] ({name: np.ndarray})."""
        self.wait()  # an in-flight async save may be about to commit `step`
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None, None
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "meta.msgpack"), "rb") as f:
            meta = msgpack.unpackb(f.read())
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves = [data[f"leaf_{i}"] for i in range(len(data.files))]
        if target is not None:
            treedef = jax.tree_util.tree_structure(target)
            tree = jax.tree_util.tree_unflatten(treedef, leaves)
        else:
            raise ValueError("restore requires a `target` pytree for the treedef")
        if shardings is not None:
            def put(x, s):
                if isinstance(s, jax.sharding.Sharding):
                    x = np.asarray(x)
                    return jax.make_array_from_callback(
                        x.shape, s, lambda idx: x[idx])
                return jax.device_put(x, s)
            tree = jax.tree_util.tree_map(put, tree, shardings)
        extra = json.loads(meta["extra"]) if meta.get("extra") else {}
        xa_path = os.path.join(path, "extra_arrays.npz")
        if os.path.exists(xa_path):
            with np.load(xa_path) as xa:
                extra["_arrays"] = {k: xa[k] for k in xa.files}
        return tree, meta["step"], extra
