"""internvl2-2b [vlm] — InternLM2-1.8B decoder backbone; InternViT STUB frontend.

input_specs() provides 256 precomputed patch embeddings at d_model (the ViT +
mlp1 projector is stubbed per the assignment spec). [arXiv:2404.16821]
"""
from repro.configs.base import ModelConfig, SpionConfig, register

INTERNVL2_2B = register(ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2_048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8_192,
    vocab_size=92_553,
    tie_embeddings=False,
    rope_theta=1e6,
    act="silu",
    num_patch_tokens=256,
    spion=SpionConfig(enabled=True, variant="cf", block_size=64),
    shape_skips=(
        ("long_500k", "pure full-attention arch (DESIGN.md §4)"),
    ),
))
