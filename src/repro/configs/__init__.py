"""Architecture registry. Importing this package registers all configs."""
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    MoEConfig,
    ShapeSpec,
    SpionConfig,
    SSMConfig,
    all_configs,
    get_config,
    register,
)

# one module per assigned architecture (+ the paper's own model)
from repro.configs import (  # noqa: F401,E402
    arctic_480b,
    command_r_35b,
    internvl2_2b,
    mistral_large_123b,
    mixtral_8x7b,
    qwen2_5_14b,
    qwen2_7b,
    rwkv6_7b,
    spion_lra,
    whisper_tiny,
    zamba2_1_2b,
)

ARCH_IDS = sorted(all_configs().keys())
