"""command-r-35b [dense] — GQA, no bias, large vocab. [hf:CohereForAI/c4ai-command-r-v01]"""
from repro.configs.base import ModelConfig, SpionConfig, register

COMMAND_R_35B = register(ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8_192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22_528,
    vocab_size=256_000,
    tie_embeddings=True,
    rope_theta=8e6,
    act="silu",
    spion=SpionConfig(enabled=True, variant="cf", block_size=128),
    shape_skips=(
        ("long_500k", "pure full-attention arch (DESIGN.md §4)"),
    ),
))
