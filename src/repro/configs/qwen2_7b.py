"""qwen2-7b [dense] — GQA kv=4, QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs.base import ModelConfig, SpionConfig, register

QWEN2_7B = register(ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3_584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18_944,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1e6,
    act="silu",
    spion=SpionConfig(enabled=True, variant="cf", block_size=128),
    shape_skips=(
        ("long_500k", "pure full-attention arch (DESIGN.md §4)"),
    ),
))
