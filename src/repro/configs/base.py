"""Config system: architecture configs, shape specs, SPION settings.

Every assigned architecture is a `ModelConfig`; input geometries are
`ShapeSpec`s. A (ModelConfig, ShapeSpec) pair fully determines one dry-run
cell. Reduced configs for CPU smoke tests come from `ModelConfig.reduced()`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Shape specs (assigned per the task: same 4 shapes for every LM arch)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int = 2
    # arctic keeps a small dense FFN residual branch in parallel with the MoE
    dense_residual_ff: int = 0


@dataclass(frozen=True)
class SSMConfig:
    state_size: int = 64       # N (d_state)
    head_dim: int = 64         # P (mamba2 head dim) / rwkv head size
    expand: int = 2            # d_inner = expand * d_model
    chunk: int = 128           # chunked-scan block length


@dataclass(frozen=True)
class SpionConfig:
    """Paper hyper-parameters (§5): F=31 conv filter, B∈{32,64} blocks,
    alpha-quantile threshold, Frobenius transition tolerance."""
    enabled: bool = False
    variant: str = "cf"            # "c" | "f" | "cf" (paper's SPION-C/F/CF)
    conv_filter_size: int = 31     # F
    block_size: int = 64           # B (avg-pool/upsample block)
    alpha_quantile: float = 0.96   # threshold t = alpha-quantile of pool_out
    transition_tol: float = 0.05   # α in Alg. 2 line 10 (Frobenius criterion)
    min_dense_epochs: int = 1
    max_dense_epochs: int = 8      # force transition even if criterion unmet
    # kernel-side: max active column-blocks per row-block (padded BCSR width).
    # None -> derived from the generated pattern at transition time.
    max_blocks_per_row: Optional[int] = None
    # sparse-phase attention implementation: "auto" picks the fused
    # differentiable Pallas kernel where its compiled lane exists (TPU
    # Mosaic today; under a mesh, whenever a kernel dim shards) and the
    # pure-jnp BCSR path elsewhere; "fused" / "jnp" force one (fused on
    # CPU runs the Pallas interpreter — correct but slow, used by the
    # gradient tests; on GPU it engages the Triton lowering).
    kernel: str = "auto"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense|moe|ssm|hybrid|encdec|vlm|audio|encoder
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads
    qkv_bias: bool = False                  # qwen2 family uses QKV bias
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None    # mixtral SWA
    norm_eps: float = 1e-5
    causal: bool = True                     # decoder LMs; encoder-only = False
    act: str = "silu"                       # "silu" (gated) | "relu" | "gelu"
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): apply a shared attention block every k-th ssm layer
    hybrid_attn_every: int = 0
    # enc-dec (whisper backbone): encoder layer count (decoder = num_layers)
    encoder_layers: int = 0
    encoder_causal: bool = False
    # vlm stub frontend: number of precomputed patch embeddings prepended
    num_patch_tokens: int = 0
    # SPION
    spion: SpionConfig = field(default_factory=SpionConfig)
    # which shapes are inapplicable for this arch ("skip:<reason>")
    shape_skips: Tuple[Tuple[str, str], ...] = ()
    dtype: str = "bfloat16"
    # KV-cache storage dtype (None -> dtype). float8_e4m3fn halves decode
    # cache memory; compute stays in `dtype` (cast on read).
    cache_dtype: "Optional[str]" = None
    remat: bool = True          # activation checkpointing in scan-over-layers
    # activation sharding between blocks: None | "d" (model-shard d_model) |
    # "seq" (Megatron-SP style: model-shard the sequence dim)
    act_shard: Optional[str] = None
    # pin the per-layer partial-sum all-reduces to bf16 (an optimization
    # barrier stops XLA hoisting the norm's fp32 upcast above the AR, which
    # doubles wire bytes)
    ar_bf16: bool = False
    # scan unroll factor (layers & ssm chunk scans). The dry-run sets this to
    # full unroll so compiled.cost_analysis() counts every layer (XLA counts a
    # while-loop body once); production training keeps 1 for compile speed.
    scan_unroll: int = 1

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    def skip_reason(self, shape_name: str) -> Optional[str]:
        for s, reason in self.shape_skips:
            if s == shape_name:
                return reason
        return None

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests (one fwd/train step)."""
        kw = dict(
            num_layers=min(self.num_layers, 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads < self.num_heads else 4,
            d_ff=128,
            vocab_size=128,
            head_dim=16,
            sliding_window=64 if self.sliding_window else None,
            remat=False,
        )
        if self.moe is not None:
            kw["moe"] = MoEConfig(
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                dense_residual_ff=32 if self.moe.dense_residual_ff else 0,
            )
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(state_size=8, head_dim=16, expand=2, chunk=16)
        if self.encoder_layers:
            kw["encoder_layers"] = min(self.encoder_layers, 2)
        if self.num_patch_tokens:
            kw["num_patch_tokens"] = 4
        if self.hybrid_attn_every:
            kw["hybrid_attn_every"] = 2
        return self.replace(**kw)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs in roofline)."""
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        hd = self.resolved_head_dim
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        attn = q + kv + o
        if self.act in ("silu", "swiglu"):
            mlp = 3 * d * ff  # gated
        else:
            mlp = 2 * d * ff
        if self.moe is not None:
            mlp = self.moe.num_experts * mlp + d * self.moe.num_experts
            if self.moe.dense_residual_ff:
                mlp += 3 * d * self.moe.dense_residual_ff
        if self.family == "ssm":  # rwkv6: tokenshift/wkv/gates approximated by zoo layer defs
            inner = self.ssm.expand * d if self.ssm else 2 * d
            attn = 4 * d * inner  # r,k,v,g projections
            mlp = 2 * d * ff
        block = attn + mlp + 2 * d
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = L * block + emb + d
        if self.encoder_layers:
            total += self.encoder_layers * block + self.encoder_layers * attn  # cross-attn
        if self.hybrid_attn_every:
            total += attn + 2 * d  # one shared attention block
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts instead of all)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        per_expert = 3 * d * ff
        inactive = L * (self.moe.num_experts - self.moe.top_k) * per_expert
        return int(full - inactive)


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (ensures all arch modules imported)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> dict:
    import repro.configs  # noqa: F401
    return dict(_REGISTRY)
