"""arctic-480b [moe] — 128 experts top-2 + dense FFN residual. [hf:Snowflake/snowflake-arctic-base]"""
from repro.configs.base import ModelConfig, MoEConfig, SpionConfig, register

ARCTIC_480B = register(ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7_168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4_864,
    vocab_size=32_000,
    rope_theta=1e4,
    act="silu",
    moe=MoEConfig(num_experts=128, top_k=2, dense_residual_ff=7_168),
    spion=SpionConfig(enabled=True, variant="cf", block_size=128),
    shape_skips=(
        ("long_500k", "pure full-attention arch (DESIGN.md §4)"),
    ),
))
