"""qwen2.5-14b [dense] — GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B family; hf]"""
from repro.configs.base import ModelConfig, SpionConfig, register

QWEN2_5_14B = register(ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5_120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=13_824,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1e6,
    act="silu",
    spion=SpionConfig(enabled=True, variant="cf", block_size=128),
    shape_skips=(
        ("long_500k", "pure full-attention arch; 512k dense-KV decode is "
                      "quadratic with no sub-quadratic mechanism (DESIGN.md §4)"),
    ),
))
