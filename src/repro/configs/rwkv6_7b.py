"""rwkv6-7b [ssm] — Finch, attention-free, data-dependent decay. [arXiv:2404.05892]

SPION inapplicable: no attention-score matrix exists (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, SpionConfig, SSMConfig, register

RWKV6_7B = register(ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4_096,
    num_heads=64,           # wkv heads = d_model / head_dim
    num_kv_heads=64,
    d_ff=14_336,
    vocab_size=65_536,
    act="relu",             # rwkv channel-mix uses squared relu
    ssm=SSMConfig(state_size=64, head_dim=64, expand=1, chunk=128),
    spion=SpionConfig(enabled=False),  # attention-free
    # sub-quadratic by construction: all 4 shapes runnable
))
