"""The paper's own model: encoder-only Transformer for LRA (§5).

D=64 embedding, post-split head dim 64/H; the paper uses small LRA-standard
encoders. Three task presets share this family with different (L, B, alpha):
image classification L=1024 B=32 alpha=.96; ListOps L=2048 B=64 alpha=.98;
document retrieval L=4096 B=64 alpha=.99.
"""
from repro.configs.base import ModelConfig, SpionConfig, register

SPION_LRA = register(ModelConfig(
    name="spion-lra",
    family="encoder",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=256,
    vocab_size=512,          # byte/pixel-level vocab upper bound across tasks
    causal=False,
    act="relu",
    rope_theta=0.0,          # learned positions, as in LRA encoders
    spion=SpionConfig(enabled=True, variant="cf", conv_filter_size=31,
                      block_size=64, alpha_quantile=0.98, transition_tol=0.05),
    shape_skips=(
        ("decode_32k", "encoder-only model has no decode step"),
        ("long_500k", "encoder-only model has no decode step"),
    ),
))

# task presets (paper §5 hyper-parameters)
LRA_TASKS = {
    "image": dict(seq_len=1_024, batch=256, block_size=32, alpha=0.96, classes=10),
    "listops": dict(seq_len=2_048, batch=128, block_size=64, alpha=0.98, classes=10),
    "retrieval": dict(seq_len=4_096, batch=32, block_size=64, alpha=0.99, classes=2),
}
