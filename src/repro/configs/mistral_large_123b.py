"""mistral-large-123b [dense] — 88L GQA. [hf:mistralai/Mistral-Large-Instruct-2407]"""
from repro.configs.base import ModelConfig, SpionConfig, register

MISTRAL_LARGE_123B = register(ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12_288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=28_672,
    vocab_size=32_768,
    head_dim=128,
    rope_theta=1e6,
    act="silu",
    spion=SpionConfig(enabled=True, variant="cf", block_size=128),
    shape_skips=(
        ("long_500k", "pure full-attention arch (DESIGN.md §4)"),
    ),
))
