"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention. [arXiv:2401.04088]"""
from repro.configs.base import ModelConfig, MoEConfig, SpionConfig, register

MIXTRAL_8X7B = register(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4_096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=32_000,
    sliding_window=4_096,
    rope_theta=1e6,
    act="silu",
    moe=MoEConfig(num_experts=8, top_k=2),
    spion=SpionConfig(enabled=True, variant="cf", block_size=128),
    # SWA makes decode attention O(window) -> long_500k IS runnable
))
