"""whisper-tiny [audio] — enc-dec backbone, conv frontend STUB. [arXiv:2212.04356]

input_specs() provides precomputed frame embeddings (B, S_enc, 384); the
conv1d+GELU mel frontend is stubbed per the assignment spec.
"""
from repro.configs.base import ModelConfig, SpionConfig, register

WHISPER_TINY = register(ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,            # decoder layers
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1_536,
    vocab_size=51_865,
    act="gelu",
    rope_theta=0.0,          # whisper uses learned/sinusoidal positions
    spion=SpionConfig(enabled=True, variant="cf", block_size=64),
    shape_skips=(
        ("long_500k", "pure full-attention enc-dec (DESIGN.md §4)"),
    ),
))
