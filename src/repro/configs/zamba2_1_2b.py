"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block. [arXiv:2411.15242]

SPION applies to the shared attention blocks only (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, SpionConfig, SSMConfig, register

ZAMBA2_1_2B = register(ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2_048,
    num_heads=32,
    num_kv_heads=32,        # GQA kv=32 == MHA
    d_ff=8_192,
    vocab_size=32_000,
    act="gelu",
    ssm=SSMConfig(state_size=64, head_dim=64, expand=2, chunk=128),
    hybrid_attn_every=6,    # shared attention block applied every 6th layer
    spion=SpionConfig(enabled=True, variant="cf", block_size=128),
    # hybrid: mamba2 state decode is O(1)/token -> long_500k runnable
))
