from repro.data.listops import make_listops_batch, generate_listops  # noqa: F401
from repro.data.synthetic import lm_batch_iterator, synthetic_task_batch  # noqa: F401
from repro.data.pipeline import ShardedBatcher  # noqa: F401
