"""ListOps generator — the real task from Nangia & Bowman (2018), as used by
LRA and the paper's §5 ListOps evaluation. Offline container: we generate
the dataset from the original grammar instead of downloading it.

Grammar: expressions over {MIN, MAX, MED, SM (sum mod 10)} applied to digits
0-9, arbitrary nesting. Tokenised to a fixed vocab; padded to max_len.
"""
from __future__ import annotations

import numpy as np

OPS = ["MIN", "MAX", "MED", "SM"]
# vocab: 0 PAD, 1 CLS, 2 (, 3 ), 4-7 ops, 8-17 digits
PAD, CLS, OPEN, CLOSE = 0, 1, 2, 3
OP0 = 4
DIG0 = 8
VOCAB_SIZE = 18


def _sample_tree(rng, depth, max_args):
    if depth <= 0 or rng.random() < 0.3:
        return int(rng.integers(0, 10))
    op = OPS[rng.integers(0, len(OPS))]
    n = int(rng.integers(2, max_args + 1))
    return (op, [_sample_tree(rng, depth - 1, max_args) for _ in range(n)])


def _eval(node):
    if isinstance(node, int):
        return node
    op, args = node
    vals = [_eval(a) for a in args]
    if op == "MIN":
        return min(vals)
    if op == "MAX":
        return max(vals)
    if op == "MED":
        return int(np.median(vals))
    return sum(vals) % 10


def _tokens(node, out):
    if isinstance(node, int):
        out.append(DIG0 + node)
        return
    op, args = node
    out.append(OPEN)
    out.append(OP0 + OPS.index(op))
    for a in args:
        _tokens(a, out)
    out.append(CLOSE)


def generate_listops(rng, max_len, depth=6, max_args=5):
    """One (tokens, label) sample, retrying until it fits max_len."""
    while True:
        tree = _sample_tree(rng, depth, max_args)
        toks = [CLS]
        _tokens(tree, toks)
        if 8 <= len(toks) <= max_len:
            arr = np.full((max_len,), PAD, np.int32)
            arr[: len(toks)] = toks
            return arr, _eval(tree)


def make_listops_batch(rng, batch, max_len, depth=6):
    xs = np.zeros((batch, max_len), np.int32)
    ys = np.zeros((batch,), np.int32)
    for i in range(batch):
        xs[i], ys[i] = generate_listops(rng, max_len, depth)
    return xs, ys
