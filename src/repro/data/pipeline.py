"""Sharded host->device data pipeline: background prefetch thread + per-shard
placement with jax.device_put under a NamedSharding (multi-host: each process
feeds its addressable shards — same API, jax.make_array_from_process_local_data).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ShardedBatcher:
    """Wraps a host iterator of numpy batches; prefetches `depth` batches and
    places them according to `spec` on `mesh` (batch dim over data axes)."""

    def __init__(self, it: Iterator, mesh: Optional[Mesh] = None,
                 spec: Optional[P] = None, depth: int = 2):
        self.it = it
        self.mesh = mesh
        self.spec = spec
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.err = None
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _place(self, batch):
        if self.mesh is None:
            return jax.tree_util.tree_map(jax.numpy.asarray, batch)
        sh = NamedSharding(self.mesh, self.spec if self.spec is not None
                           else P(tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)))

        def put(x):
            full = NamedSharding(self.mesh, P(*([sh.spec[0]] + [None] * (x.ndim - 1))))
            return jax.device_put(x, full)
        return jax.tree_util.tree_map(put, batch)

    def _worker(self):
        try:
            for b in self.it:
                if self._stop.is_set():
                    return
                self.q.put(self._place(b))
            self.q.put(StopIteration)
        except Exception as e:  # surface on next()
            self.err = e
            self.q.put(StopIteration)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is StopIteration:
            if self.err:
                raise self.err
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
