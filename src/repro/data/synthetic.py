"""Synthetic stand-ins for the offline container: LM token streams (for the
assigned-arch smoke/bench paths) plus geometry-matched versions of the
paper's other two LRA tasks (pixel-sequence classification, byte-level
document matching). See DESIGN.md §6 for the validation strategy.
"""
from __future__ import annotations

import numpy as np


def lm_batch_iterator(rng, *, batch, seq_len, vocab, structured=True):
    """Infinite synthetic LM stream. `structured` mixes short-range
    (copy/ngram) structure so losses actually go down during examples."""
    while True:
        if structured:
            base = rng.integers(0, vocab, size=(batch, seq_len // 4 + 1))
            toks = np.repeat(base, 4, axis=1)[:, :seq_len]
            noise = rng.random((batch, seq_len)) < 0.1
            toks = np.where(noise, rng.integers(0, vocab, size=(batch, seq_len)), toks)
        else:
            toks = rng.integers(0, vocab, size=(batch, seq_len))
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        yield {"tokens": tokens, "labels": labels}


def synthetic_task_batch(rng, task, *, batch, seq_len, num_classes=10):
    """Paper-geometry classification batches:
      image:     pixel sequences (L=1024 in the paper) whose class controls a
                 2-D frequency pattern — requires long-range aggregation.
      retrieval: two byte docs concatenated; label = shared-prefix parity.
    """
    if task == "image":
        cls = rng.integers(0, num_classes, size=(batch,))
        t = np.arange(seq_len)
        freq = (cls[:, None] + 1) * 2 * np.pi / seq_len
        wave = np.sin(freq * t[None, :]) + 0.3 * rng.standard_normal((batch, seq_len))
        toks = np.clip(((wave + 2) / 4 * 255), 0, 255).astype(np.int32)
        return toks, cls.astype(np.int32)
    if task == "retrieval":
        half = seq_len // 2
        a = rng.integers(0, 256, size=(batch, half))
        same = rng.random(batch) < 0.5
        b = np.where(same[:, None], a, rng.integers(0, 256, size=(batch, half)))
        toks = np.concatenate([a, b], axis=1).astype(np.int32)
        return toks, same.astype(np.int32)
    raise ValueError(task)
