"""Continuous-batching serving: a reduced qwen2-7b-family model answering
more requests than it has cache slots — fused prefill straight into slots,
one batched decode per tick at per-slot positions, admission mid-decode —
then the same batch served SPARSELY from a SPION-style plan (decode gathers
only the pattern-listed KV-cache blocks), then the paged-cache payoff: a
SHARED SYSTEM PROMPT prefilled once and copy-on-write-mapped into every
later request (DESIGN.md §14).

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import Request, ServeEngine
from repro.models.registry import build


def make_requests(cfg, rng, n):
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(4, 12)))
                    .astype(np.int32),
                    max_new=12) for i in range(n)]


def full_causal_tables(layers, nrb, block):
    """A fully-covering stand-in plan (a real run would use the trained
    SparsityPlan: SpionController.attention_exec(state, phase='decode'))."""
    from repro.launch.steps import causal_band_tables
    return dict(causal_band_tables(layers, nrb), block=block)


def serve(eng, reqs, label):
    t0 = time.time()
    eng.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.out) for r in reqs)
    for r in reqs:
        print(f"  req {r.rid}: P={len(r.prompt)} -> {r.out}")
    print(f"  [{label}] {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s batched on CPU)\n")


def main():
    cfg = get_config("qwen2-7b").reduced().replace(remat=False)
    bundle = build(cfg)
    params = bundle.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    max_len, slots = 64, 4

    print(f"{slots} slots, 6 requests (mixed lengths; slots are reused):")
    serve(ServeEngine(cfg, params, slots=slots, max_len=max_len),
          make_requests(cfg, rng, 6), "dense decode")

    tabs = full_causal_tables(cfg.num_layers, max_len // 8, 8)
    print("same engine, sparse decode from a covering plan (logits match the\n"
          "dense path to kernel tolerance; greedy tokens can still diverge on\n"
          "random bf16 weights once one near-tie flips):")
    serve(ServeEngine(cfg, params, slots=slots, max_len=max_len, spion=tabs),
          make_requests(cfg, np.random.default_rng(0), 6), "sparse decode")

    # paged cache + COW prefix sharing: every request carries the same
    # 32-token system prompt; the engine prefills it ONCE, later requests
    # incref the same physical pages and only their private suffix is
    # computed (an exact repeat reuses the cached first token outright)
    print("shared system prompt across 5 requests (paged cache, COW):")
    sys_prompt = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    reqs = [Request(rid=i,
                    prompt=np.concatenate(
                        [sys_prompt,
                         rng.integers(0, cfg.vocab_size,
                                      int(rng.integers(2, 6))).astype(np.int32)]),
                    max_new=12) for i in range(5)]
    eng = ServeEngine(cfg, params, slots=slots, max_len=max_len,
                      page_size=8)        # 32-token prefix = 4 shared pages
    serve(eng, reqs, "paged + shared prefix")
    st = eng.prefix_stats
    print(f"  prefix hit rate {st['prefix_hit_rate']:.2f} "
          f"({st['hits']}/{st['lookups']} page lookups), "
          f"{st['prefill_fused']} fused prefill(s) for 5 requests, "
          f"{st['prefix_tokens_reused']} prompt tokens reused, "
          f"{st['forks']} COW fork(s)")


if __name__ == "__main__":
    main()
