"""Batched serving: a reduced qwen2-7b-family model answering a batch of
requests through the slot-based engine (prefill + batched greedy decode).

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import Request, ServeEngine
from repro.models.registry import build


def main():
    cfg = get_config("qwen2-7b").reduced().replace(remat=False)
    bundle = build(cfg)
    params = bundle.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=4, max_len=64)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=6).astype(np.int32),
                    max_new=12) for i in range(4)]
    t0 = time.time()
    eng.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in reqs)
    for r in reqs:
        print(f"req {r.rid}: prompt={r.prompt.tolist()} -> {r.out}")
    print(f"\n{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s batched on CPU)")


if __name__ == "__main__":
    main()
