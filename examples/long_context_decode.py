"""Long-context decode with a state-space model: rwkv6-family decode cost is
O(1) per token regardless of context length (the long_500k dry-run cell at
full scale). Decodes at several "virtual context lengths" and shows the
constant per-token cost + fixed-size recurrent state.

    PYTHONPATH=src python examples/long_context_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.registry import build


def main():
    cfg = get_config("rwkv6-7b").reduced().replace(remat=False)
    bundle = build(cfg)
    params = bundle.init(jax.random.key(0))
    B = 1
    cache = bundle.init_cache(B, max_len=1)   # state size independent of L!
    state_bytes = sum(np.prod(v.shape) * v.dtype.itemsize
                      for v in jax.tree_util.tree_leaves(cache))
    print(f"recurrent state: {state_bytes/1e3:.1f} KB "
          f"(vs a 512k-token KV cache of a same-size transformer: "
          f"{cfg.num_layers*524288*cfg.num_kv_heads*16*2*2/1e9:.1f} GB)")

    decode = jax.jit(bundle.decode_step, donate_argnums=(1,))
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache = decode(params, cache, tok, jnp.int32(0))  # warm up
    for virtual_pos in (1_000, 100_000, 524_288):
        t0 = time.perf_counter()
        for i in range(20):
            logits, cache = decode(params, cache, tok, jnp.int32(virtual_pos + i))
        jax.block_until_ready(logits)
        dt = (time.perf_counter() - t0) / 20 * 1e3
        print(f"context {virtual_pos:>8,}: {dt:6.2f} ms/token  (flat = O(1)/token)")


if __name__ == "__main__":
    main()
