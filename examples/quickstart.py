"""Quickstart: SPION end to end in ~a minute on CPU.

Builds the paper's encoder model at reduced scale, trains dense for a few
epochs, watches the Frobenius criterion trigger the transition, generates the
layer-wise conv-flood-fill patterns, and finishes training sparse.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import SpionConfig, get_config
from repro.launch.train import Trainer


def main():
    cfg = get_config("spion-lra").replace(
        num_layers=2, d_ff=128, vocab_size=64,
        spion=SpionConfig(enabled=True, variant="cf", conv_filter_size=7,
                          block_size=16, alpha_quantile=0.85,
                          transition_tol=0.5, min_dense_epochs=1,
                          max_dense_epochs=4))
    tr = Trainer(cfg, seq_len=128, batch=8, lr=1e-3, steps_per_epoch=10)
    losses = tr.train(80, ckpt_every=0, log_every=10)
    print(f"\nfinal phase: {tr.spion_state.phase}")
    print(f"pattern density: {tr.spion_state.density:.3f} "
          f"(attention sparsity {1 - tr.spion_state.density:.1%})")
    print(f"loss: {np.mean(losses[:10]):.3f} -> {np.mean(losses[-10:]):.3f}")
    assert tr.spion_state.phase == "sparse"


if __name__ == "__main__":
    main()
