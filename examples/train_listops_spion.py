"""End-to-end driver: train a ~100M-parameter encoder on generated ListOps
(the paper's §5 task, real grammar) for a few hundred steps through all three
SPION phases, with checkpointing and crash-restart enabled.

    PYTHONPATH=src python examples/train_listops_spion.py [--steps 300]

~100M params: d_model=512, 6 layers, d_ff=2048, vocab=18 -> 20M... the bulk
comes from d_model=768/12L (BERT-base geometry, 86M + embeddings).
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.configs import SpionConfig, get_config
from repro.data.listops import VOCAB_SIZE, make_listops_batch
from repro.launch.train import Trainer


def listops_iter(rng, batch, seq_len):
    while True:
        xs, _ = make_listops_batch(rng, batch, seq_len + 1, depth=5)
        yield {"tokens": xs[:, :-1], "labels": xs[:, 1:]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--dim", type=int, default=768)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--ckpt", default="/tmp/spion_listops_ckpt")
    args = ap.parse_args()

    cfg = get_config("spion-lra").replace(
        num_layers=args.layers, d_model=args.dim, num_heads=args.dim // 64,
        num_kv_heads=args.dim // 64, d_ff=4 * args.dim, vocab_size=VOCAB_SIZE,
        head_dim=64,
        spion=SpionConfig(enabled=True, variant="cf", conv_filter_size=15,
                          block_size=32, alpha_quantile=0.9,
                          transition_tol=0.05, min_dense_epochs=1,
                          max_dense_epochs=4))
    print(f"params: {cfg.param_count()/1e6:.1f}M")
    rng = np.random.default_rng(0)
    tr = Trainer(cfg, seq_len=args.seq_len, batch=args.batch, lr=3e-4,
                 steps_per_epoch=25, ckpt_dir=args.ckpt,
                 data_iter=listops_iter(rng, args.batch, args.seq_len))
    if tr.maybe_resume():
        print(f"resumed from step {tr.step} (phase {tr.spion_state.phase})")
    losses = tr.train(args.steps, ckpt_every=100, log_every=10)
    print(f"\nphase={tr.spion_state.phase} density={tr.spion_state.density}")
    print(f"loss {np.mean(losses[:10]):.3f} -> {np.mean(losses[-10:]):.3f}")


if __name__ == "__main__":
    main()
