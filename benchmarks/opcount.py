"""§4.4 computational-complexity table: exact op-count identities.

dense  A: 2 L^2 (2D+1) - L (D+1)
sparse S: 2 C   (2D+1) - L (D+1)     (C = stored elements)
The paper's AAN example (L=4096, D=64, C=10% of L^2) gives
4,328,255,488 vs 432,585,778 — reproduced exactly.

Purely analytic — no timed regions. Any timing added here must go through
benchmarks/timing.time_us (warmup discarded, min-of-reps,
block_until_ready), the shared hygiene every wall-clock row follows.
"""
from __future__ import annotations


def dense_ops(L: int, D: int) -> int:
    return 2 * L * L * (2 * D + 1) - L * (D + 1)


def sparse_ops(C: int, L: int, D: int) -> int:
    return 2 * C * (2 * D + 1) - L * (D + 1)


def rows(out):
    L, D = 4096, 64
    C = 1_677_721  # paper: 10% of L^2 (ncd)
    d = dense_ops(L, D)
    s = sparse_ops(C, L, D)
    out("opcount.dense_AAN", d, f"paper=4328255488 match={d == 4_328_255_488}")
    out("opcount.sparse_AAN", s, f"paper=432585778 match={s == 432_585_778}")
    out("opcount.reduction", round(d / s, 3), "paper~10x")
    # the paper's three tasks at their configured sparsity
    for task, L_, alpha in [("image", 1024, 0.96), ("listops", 2048, 0.98),
                            ("retrieval", 4096, 0.99)]:
        C_ = int((1 - alpha) * L_ * L_)
        out(f"opcount.{task}_reduction",
            round(dense_ops(L_, 64) / sparse_ops(max(C_, 1), L_, 64), 2),
            f"alpha={alpha}")
