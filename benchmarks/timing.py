"""Shared wall-clock timing hygiene for every benchmark module.

One discipline, one place: dispatch-warm the jitted callable first (the
warmup reps — compile + first-run caches — are DISCARDED), then report the
MIN over `reps` timed calls, each bracketed by `jax.block_until_ready` so
async dispatch can't leak a rep's work into the next rep's window. Min, not
mean: on shared CI runners the distribution is one clean floor plus
noisy-neighbour outliers, and the floor is the number that tracks the code.
"""
from __future__ import annotations

import time

import jax


def time_us(fn, *args, reps: int = 5, warmup: int = 1) -> float:
    """Min-of-`reps` wall time of ``fn(*args)`` in microseconds."""
    for _ in range(max(int(warmup), 1)):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(max(int(reps), 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6
