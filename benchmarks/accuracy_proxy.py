"""Table 2 proxy: convergence quality of dense vs SPION-C/F/CF vs fixed
patterns on generated ListOps (reduced scale; the real LRA datasets are not
available offline — DESIGN.md §6). Reports train-loss after a fixed budget;
lower = better. SPION variants must stay within noise of dense."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.pattern import generate_pattern
from repro.core.sparse_attention import bcsr_from_blockmask
from repro.core.variants import fixed_pattern_tables
from repro.data.listops import VOCAB_SIZE, make_listops_batch
from repro.launch.steps import make_train_step
from repro.models.registry import build
from repro.optim import adamw_init

STEPS = 30
L, BLOCK, BATCH = 256, 32, 8


def _train(cfg, tables, steps=STEPS, seed=0):
    bundle = build(cfg)
    params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.ndim >= 2 else x,
        bundle.init(jax.random.key(seed)))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, spion=tables is not None, lr=1e-3, block=BLOCK))
    rng = np.random.default_rng(seed)
    losses = []
    for i in range(steps):
        xs, ys = make_listops_batch(rng, BATCH, L + 1, depth=4)
        batch = {"tokens": jnp.asarray(xs[:, :-1]),
                 "labels": jnp.asarray(xs[:, 1:])}
        args = (params, opt, batch, jnp.int32(i)) + ((tables,) if tables is not None else ())
        params, opt, m = step(*args)
        losses.append(float(m["loss"]))
    return float(np.mean(losses[-5:]))


def rows(out):
    cfg = get_config("spion-lra").replace(num_layers=2, d_ff=128,
                                          vocab_size=VOCAB_SIZE)
    n = L // BLOCK
    rng = np.random.default_rng(0)
    scores = rng.random((L, L))
    base = _train(cfg, None)
    out("accuracy.dense_loss", round(base, 4), "dense baseline (LM loss on ListOps)")
    for variant in ("c", "f", "cf"):
        pat = generate_pattern(scores, variant=variant, conv_filter_size=7,
                               block_size=BLOCK, alpha_quantile=0.85)
        b = bcsr_from_blockmask(pat, BLOCK)
        tabs = {"col_idx": jnp.stack([b.col_idx] * cfg.num_layers),
                "nvalid": jnp.stack([b.nvalid] * cfg.num_layers),
                "block": BLOCK}
        l = _train(cfg, tabs)
        out(f"accuracy.spion_{variant}_loss", round(l, 4),
            f"delta_vs_dense={l-base:+.4f} density={pat.mean():.3f}")
    tabs = fixed_pattern_tables("bigbird", L, BLOCK, cfg.num_layers)
    out("accuracy.bigbird_loss", round(_train(cfg, tabs), 4), "fixed-pattern baseline")
