"""Autotune lane rows: the tune -> cache -> dispatch loop, end to end.

Runs a real candidate sweep (kernels/autotune.tune) on a small synthetic
pattern into the session's SPION_AUTOTUNE_DIR (CI points this at a
workspace dir and uploads it as an artifact), then proves the lane closes:
a cold construction of SparseAttentionExec hits the freshly persisted entry
(`autotune.cache_hit` = 1) and the tuned config's output is bitwise equal
to the default's (`autotune.bitwise_ok` — the sweep disqualifies any
candidate that isn't).

On interpreter hosts (CPU CI) the sweep times the Pallas interpreter, so
the winning depth is noise — the rows assert the MECHANICS (sweep size,
cache hit, bitwise identity), not which candidate won.
"""
from __future__ import annotations

import os


def rows(out, smoke=False):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.attention_exec import SparseAttentionExec
    from repro.core.sparse_attention import bcsr_from_blockmask
    from repro.kernels import autotune
    from repro.kernels.block_sparse_attn import fused_block_sparse_attention
    from repro.kernels.dispatch import DEFAULT_CONFIG

    L, block = (128, 16) if smoke else (256, 32)
    n = L // block
    rng = np.random.default_rng(0)
    mask = rng.random((n, n)) < 0.3
    np.fill_diagonal(mask, True)
    b = bcsr_from_blockmask(mask, block)
    tables = {"col_idx": b.col_idx, "nvalid": b.nvalid}

    best, report = autotune.tune(tables, block, head_dim=32,
                                 reps=2 if smoke else 3)
    best_us = min(r["us"] for r in report if r["config"] == best)
    out("autotune.swept", len(report),
        f"candidates timed for backend={autotune._backend_name()} "
        f"dir={os.path.basename(autotune.cache_dir())}")
    out("autotune.best_us", round(best_us, 1),
        f"winner: {autotune.describe(best)} (interpreter hosts: "
        "mechanics anchor, not a schedule claim)")
    out("autotune.bitwise_ok", int(all(r["bitwise"] for r in report)),
        "every candidate's output bitwise == default's (disqualify rule)")

    # the consumer side: a fresh exec consults the cache at construction
    ex = SparseAttentionExec(tables, block=block, kernel="fused")
    hit = ex.kernel_config == best
    out("autotune.cache_hit", int(hit),
        f"SparseAttentionExec construction picked up "
        f"{autotune.describe(ex.kernel_config)} from the on-disk cache")

    # and the tuned config really is result-neutral through the kernel
    col = jnp.maximum(b.col_idx, 0)
    q = jax.random.normal(jax.random.key(0), (2, 1, L, 32))
    k = jax.random.normal(jax.random.key(1), (2, L, 32))
    v = jax.random.normal(jax.random.key(2), (2, L, 32))
    o_t = fused_block_sparse_attention(q, k, v, col, b.nvalid, block=block,
                                       interpret=True, config=best)
    o_d = fused_block_sparse_attention(q, k, v, col, b.nvalid, block=block,
                                       interpret=True, config=DEFAULT_CONFIG)
    out("autotune.tuned_output_bitwise", int(np.array_equal(np.asarray(o_t),
                                                            np.asarray(o_d))),
        "tuned vs default forward outputs bitwise identical")
