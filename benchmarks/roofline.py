"""§Roofline reader: aggregates artifacts/dryrun/*.json into the roofline
table (compute/memory/collective terms, dominant bottleneck, MODEL_FLOPS
ratio). Run the dry-run first: PYTHONPATH=src python -m repro.launch.dryrun."""
from __future__ import annotations

import glob
import json
import os

ARTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells(mesh="single"):
    cells = []
    for p in sorted(glob.glob(os.path.join(ARTS, f"*__{mesh}__*.json"))):
        try:
            cells.append(json.load(open(p)))
        except Exception:
            pass
    return cells


def rows(out):
    cells = load_cells("single")
    if not cells:
        out("roofline.missing", 0, "run repro.launch.dryrun first")
        return
    ok = [c for c in cells if c.get("status") == "ok" and "roofline" in c]
    for c in ok:
        r = c["roofline"]
        t_exec = max(r.values())
        frac = {"t_compute": "compute", "t_memory": "memory",
                "t_collective": "collective"}[c["dominant"]]
        out(f"roofline.{c['arch']}.{c['shape']}.{c['mode']}",
            round(t_exec * 1e6, 1),
            f"bound={frac} tc={r['t_compute']*1e6:.0f}us tm={r['t_memory']*1e6:.0f}us "
            f"tcoll={r['t_collective']*1e6:.0f}us useful={c.get('useful_fraction') or 0:.2f} "
            f"mem={c['memory'].get('per_device_gb', float('nan')):.1f}GiB")
    sk = [c for c in cells if c.get("status") == "skipped"]
    out("roofline.cells_ok", len(ok), f"skipped={len(sk)} (documented)")
