"""§Roofline reader + measured kernel roofline.

Two row families:

  rows()        aggregates artifacts/dryrun/*.json into the ANALYTIC
                roofline table (compute/memory/collective terms, dominant
                bottleneck). Run the dry-run first:
                PYTHONPATH=src python -m repro.launch.dryrun.
  kernel_rows() MEASURED %-of-roofline per fused kernel (fwd / dQ / dK,dV):
                times each Pallas kernel (benchmarks/timing hygiene),
                derives achieved FLOP/s from the analytic block-sparse op
                count, and reports it against the roofline ceiling at that
                kernel's operational intensity — min(peak_flops,
                OI * peak_bytes_s). Peaks come from a small per-backend
                table, overridable via SPION_PEAK_FLOPS / SPION_PEAK_BYTES_S
                (so a real TPU/GPU host can pin its datasheet numbers). On
                CPU the kernels run the Pallas interpreter: the percentages
                are tiny and NOT a performance claim — the rows exist so the
                compiled-lane trajectory has a per-kernel anchor CI can gate
                on (benchmarks/check_regression.py).
"""
from __future__ import annotations

import glob
import json
import os

ARTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

# (peak FLOP/s, peak bytes/s) per compiled-lane backend; "cpu" is a
# deliberately modest interpreter-host placeholder
_PEAKS = {"tpu": (275e12, 1.2e12), "gpu": (312e12, 2.0e12),
          "cpu": (1.0e11, 4.0e10)}


def _peaks():
    from repro.kernels.dispatch import compiled_backend
    backend = compiled_backend() or "cpu"
    flops, bw = _PEAKS[backend]
    return (backend,
            float(os.environ.get("SPION_PEAK_FLOPS", flops)),
            float(os.environ.get("SPION_PEAK_BYTES_S", bw)))


def kernel_rows(out, smoke=False):
    """Measured %-of-roofline for the fused fwd / dQ / dK,dV kernels."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.timing import time_us
    from repro.core.sparse_attention import (bcsr_from_blockmask,
                                             bcsr_transpose)
    from repro.kernels.block_sparse_attn import (_fused_dkv, _fused_dq,
                                                 _fused_forward)
    from repro.kernels.dispatch import default_interpret

    L, block, hd, N, G = (128, 16, 32, 2, 1) if smoke else (256, 32, 32, 2, 1)
    n = L // block
    rng = np.random.default_rng(0)
    mask = rng.random((n, n)) < 0.3
    np.fill_diagonal(mask, True)
    b = bcsr_from_blockmask(mask, block)
    col, nv = jnp.maximum(b.col_idx, 0), b.nvalid
    nnzb = int(np.asarray(nv).sum())
    itemsize, NG = 4, N * G

    key = jax.random.key(0)
    q = jax.random.normal(key, (N, G, L, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (N, L, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (N, L, hd))
    kw = dict(block=block, causal=False, sliding_window=None,
              interpret=default_interpret(None))
    o, lse = _fused_forward(q, k, v, col, nv, **kw)
    do = jax.random.normal(jax.random.fold_in(key, 3), o.shape)
    delta = jnp.sum(do * o, -1)
    ri, nvt = bcsr_transpose(col, nv, ncb=n)

    bb = block * block
    # analytic per-valid-block op/byte counts (fp32); the derived column
    # records the model so a reader can re-derive the percentages
    kernels = {
        "fused_fwd": (
            jax.jit(lambda: _fused_forward(q, k, v, col, nv, **kw)),
            NG * nnzb * (4 * bb * hd + 8 * bb),
            itemsize * (NG * (2 * L * hd + L) + NG * nnzb * 2 * block * hd)),
        "fused_dq": (
            jax.jit(lambda: _fused_dq(q, k, v, do, lse, delta, col, nv, **kw)),
            NG * nnzb * (6 * bb * hd + 10 * bb),
            itemsize * (NG * (3 * L * hd + 2 * L)
                        + NG * nnzb * 2 * block * hd)),
        "fused_dkv": (
            jax.jit(lambda: _fused_dkv(q, k, v, do, lse, delta, ri, nvt,
                                       **kw)),
            NG * nnzb * (8 * bb * hd + 10 * bb),
            itemsize * (N * 4 * L * hd
                        + NG * nnzb * (2 * block * hd + 2 * block))),
    }
    backend, peak_flops, peak_bw = _peaks()
    reps = 3 if smoke else 5
    for name, (fn, flops, nbytes) in kernels.items():
        us = time_us(fn, reps=reps)
        achieved = flops / (us * 1e-6)
        oi = flops / nbytes
        ceiling = min(peak_flops, oi * peak_bw)
        bound = "compute" if oi * peak_bw >= peak_flops else "memory"
        out(f"roofline.{name}.pct_of_peak",
            round(100.0 * achieved / ceiling, 4),
            f"{us:.1f}us {achieved / 1e9:.3f}GFLOP/s OI={oi:.1f}flop/B "
            f"{bound}-bound ceiling={ceiling / 1e9:.0f}GFLOP/s "
            f"backend={backend} nnzb={nnzb}"
            + (" (interpreter: trajectory anchor, not a perf claim)"
               if backend == "cpu" else ""))


def load_cells(mesh="single"):
    cells = []
    for p in sorted(glob.glob(os.path.join(ARTS, f"*__{mesh}__*.json"))):
        try:
            cells.append(json.load(open(p)))
        except Exception:
            pass
    return cells


def rows(out):
    cells = load_cells("single")
    if not cells:
        out("roofline.missing", 0, "run repro.launch.dryrun first")
        return
    ok = [c for c in cells if c.get("status") == "ok" and "roofline" in c]
    for c in ok:
        r = c["roofline"]
        t_exec = max(r.values())
        frac = {"t_compute": "compute", "t_memory": "memory",
                "t_collective": "collective"}[c["dominant"]]
        out(f"roofline.{c['arch']}.{c['shape']}.{c['mode']}",
            round(t_exec * 1e6, 1),
            f"bound={frac} tc={r['t_compute']*1e6:.0f}us tm={r['t_memory']*1e6:.0f}us "
            f"tcoll={r['t_collective']*1e6:.0f}us useful={c.get('useful_fraction') or 0:.2f} "
            f"mem={c['memory'].get('per_device_gb', float('nan')):.1f}GiB")
    sk = [c for c in cells if c.get("status") == "skipped"]
    out("roofline.cells_ok", len(ok), f"skipped={len(sk)} (documented)")
