"""Fig. 6: breakdown of MHA operation times — dense GEMM/softmax/GEMM vs
sparse SDDMM/sparse-softmax/SpMM — plus the `train_step` mode that times
forward+backward now that the fused kernel has a sparse backward, the
`bwd` mode that separates the dQ vs dK/dV backward kernels and proves the
SparsityPlan shrinks the dK/dV grid to the true pattern width KT*, and the
`sharded` mode that runs the sparse train step on a 4-virtual-device
(data=2, model=2) mesh in a subprocess and records jnp-vs-shard_map-fused
rows — proving the mesh-aware dispatch keeps the Pallas kernel (and its
sparse backward) on multi-device meshes — and the `seqshard` mode doing
the same on a (seq=2, data=2) mesh for the sequence-parallel
halo-exchange dispatch (DESIGN.md §10).

CPU wall-times of the jitted jnp paths (the GPU numbers in the paper are
hardware-specific; the *structure* — softmax dominating dense MHA, every
sparse op beating its dense counterpart at 90%+ sparsity — is what this
reproduces). Derived column reports op-count ratios from §4.4.

`train_step_rows` is the honesty check the paper's headline demands: SPION
claims cheaper *training*, so the number that matters is fwd+bwd, not fwd.
It times (a) attention-level value_and_grad through the dense path, the jnp
BCSR path, and — on compiled backends (TPU Mosaic / GPU Triton) — the fused
Pallas kernel with its custom-VJP backward, and (b) one full optimizer
train step in the dense vs sparse phase via launch.steps.make_train_step.

All wall clocks go through benchmarks/timing.time_us (warmup discarded,
min-of-reps, block_until_ready around every rep).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import time_us as _time  # noqa: F401 (shared hygiene)
from repro.configs import get_config
from repro.core.sparse_attention import bcsr_from_blockmask
from repro.kernels import ref as kref


def rows(out, L=1024, D=64, block=32, density=0.08):
    B, H = 2, 2
    N = B * H
    key = jax.random.key(0)
    q = jax.random.normal(key, (N, L, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (N, L, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (N, L, D))
    rng = np.random.default_rng(0)
    n = L // block
    mask = rng.random((n, n)) < density
    np.fill_diagonal(mask, True)
    bcsr = bcsr_from_blockmask(mask, block)
    col = jnp.maximum(bcsr.col_idx, 0)

    # dense pipeline
    gemm1 = jax.jit(lambda q, k: jnp.einsum("nqd,nkd->nqk", q, k) / np.sqrt(D))
    soft = jax.jit(lambda s: jax.nn.softmax(s, -1))
    gemm2 = jax.jit(lambda p, v: jnp.einsum("nqk,nkd->nqd", p, v))
    s_dense = gemm1(q, k)
    p_dense = soft(s_dense)
    t_gemm1 = _time(gemm1, q, k)
    t_soft = _time(soft, s_dense)
    t_gemm2 = _time(gemm2, p_dense, v)

    # sparse pipeline (jnp reference path of the kernels)
    sddmm = jax.jit(lambda q, k: kref.sddmm_ref(q, k, bcsr.col_idx, block=block))
    s_sp = sddmm(q, k)
    ssoft = jax.jit(lambda s: kref.sparse_softmax_ref(s, bcsr.col_idx,
                                                      block=block, seq_len=L))
    p_sp = ssoft(s_sp)
    spmm = jax.jit(lambda p, v: kref.spmm_ref(p, v, bcsr.col_idx))
    t_sddmm = _time(sddmm, q, k)
    t_ssoft = _time(ssoft, s_sp)
    t_spmm = _time(spmm, p_sp, v)

    out("mha.dense_gemm_qk_us", round(t_gemm1, 1), "")
    out("mha.dense_softmax_us", round(t_soft, 1), "")
    out("mha.dense_gemm_av_us", round(t_gemm2, 1), "")
    out("mha.sparse_sddmm_us", round(t_sddmm, 1),
        f"speedup={t_gemm1 / t_sddmm:.2f}x (paper: 2.55x image)")
    out("mha.sparse_softmax_us", round(t_ssoft, 1),
        f"speedup={t_soft / t_ssoft:.2f}x (paper: 42.4x image)")
    out("mha.sparse_spmm_us", round(t_spmm, 1),
        f"speedup={t_gemm2 / t_spmm:.2f}x (paper: 2.54x image)")
    tot_d = t_gemm1 + t_soft + t_gemm2
    tot_s = t_sddmm + t_ssoft + t_spmm
    out("mha.total_speedup", round(tot_d / tot_s, 2),
        f"density={density} dense={tot_d:.0f}us sparse={tot_s:.0f}us")


def _skewed_pattern_plan(L, block):
    """The ISSUE's skewed layer-wise pattern: layer 0 sliding-window (column
    population <= 2), layer 1 causal diagonal + global stripe at column
    nrb//2 (population nrb/2). KT* = nrb/2 < nrb, so the plan-built dK/dV
    grid is half the always-safe padded width."""
    from repro.core.sparse_attention import build_sparsity_plan
    n = L // block
    m0 = np.zeros((n, n), bool)
    for r in range(n):
        m0[r, max(r - 1, 0): r + 1] = True
    m1 = np.zeros((n, n), bool)
    np.fill_diagonal(m1, True)
    stripe = n // 2
    m1[stripe:, stripe] = True
    K = max(int(m.sum(axis=1).max()) for m in (m0, m1))
    tabs = [bcsr_from_blockmask(m, block, max_k=K) for m in (m0, m1)]
    col = np.stack([np.asarray(t.col_idx) for t in tabs])
    nv = np.stack([np.asarray(t.nvalid) for t in tabs])
    return build_sparsity_plan(col, nv, block), col, nv


def bwd_rows(out, L=256, block=16, smoke=False):
    """`bwd` mode: dQ vs dK/dV backward-kernel timings through the host-built
    SparsityPlan on the skewed synthetic pattern, asserting the dK/dV grid
    width equals the plan's KT* (not the always-safe nrb). The padded-width
    run (KT = nrb, what the under-jit bcsr_transpose fallback pays) is the
    before; the plan run (KT*) is the after."""
    from repro.core.sparse_attention import bcsr_transpose
    from repro.kernels.block_sparse_attn import (_fused_dkv, _fused_dq,
                                                 _fused_forward)
    from repro.kernels.dispatch import default_interpret

    if smoke:
        L = 128
    n = L // block
    plan, col_st, nv_st = _skewed_pattern_plan(L, block)
    kt = plan.kt_star
    assert kt < n, f"skewed pattern must shrink the grid (KT*={kt}, nrb={n})"
    # the dK/dV pallas grid is (N, ncb, row_idx.shape[-1], G): width == KT*
    assert plan.tables["row_idx"].shape[-1] == kt, \
        "plan dK/dV grid width must equal KT*"
    out("bwd.dkv_grid_width", kt,
        f"== KT* (true max column population); padded fallback would be nrb={n}")

    N, G, hd = 2, 1, 32
    key = jax.random.key(0)
    q = jax.random.normal(key, (N, G, L, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (N, L, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (N, L, hd))
    # layer 1 (global stripe) is the interesting one: its own population is
    # what drives KT*
    col = jnp.maximum(jnp.asarray(col_st[1]), 0)
    nv = jnp.asarray(nv_st[1])
    kw = dict(block=block, causal=True, sliding_window=None,
              interpret=default_interpret(None))
    o, lse = _fused_forward(q, k, v, col, nv, **kw)
    do = jax.random.normal(jax.random.fold_in(key, 3), o.shape)
    delta = jnp.sum(do * o, -1)

    t_dq = _time(jax.jit(lambda: _fused_dq(q, k, v, do, lse, delta, col, nv,
                                           **kw)))
    ri_pad, nvt_pad = bcsr_transpose(col, nv, ncb=n)          # KT = nrb
    ri_plan = plan.tables["row_idx"][1]
    nvt_plan = plan.tables["nvalid_t"][1]
    t_pad = _time(jax.jit(lambda: _fused_dkv(q, k, v, do, lse, delta,
                                             ri_pad, nvt_pad, **kw)))
    t_plan = _time(jax.jit(lambda: _fused_dkv(q, k, v, do, lse, delta,
                                              ri_plan, nvt_plan, **kw)))
    out("bwd.dq_us", round(t_dq, 1), f"row-block grid (N,G,nrb,K) nrb={n}")
    out("bwd.dkv_padded_us", round(t_pad, 1),
        f"grid (N,ncb,{n},G) — always-safe KT=nrb (per-step-transpose path)")
    out("bwd.dkv_plan_us", round(t_plan, 1),
        f"grid (N,ncb,{kt},G) — plan KT*; grid_shrink={n / kt:.2f}x "
        f"speedup={t_pad / t_plan:.2f}x")


# Child program for the `sharded` mode: jax locks the host device count at
# first init, so the 4-virtual-device mesh needs a fresh process (same
# pattern as tests/test_distributed.py). Sizes come in via SPION_BENCH_*.
_SHARDED_CHILD = r"""
import dataclasses, os, time
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_train_step, spion_dryrun_tables
from repro.models.registry import build
from repro.optim import adamw_init

L = int(os.environ["SPION_BENCH_L"])
B = int(os.environ["SPION_BENCH_B"])
reps = int(os.environ["SPION_BENCH_REPS"])
mesh = make_mesh((2, 2), ("data", "model"))
cfg = get_config("spion-lra").reduced()
cfg = cfg.replace(num_heads=4, num_kv_heads=2, head_dim=16,
                  spion=dataclasses.replace(cfg.spion, block_size=16))
bundle = build(cfg)
params = jax.tree_util.tree_map(
    lambda x: x.astype(jnp.float32) if x.ndim >= 2 else x,
    bundle.init(jax.random.key(0)))
opt = adamw_init(params)
rng = np.random.default_rng(0)
raw = rng.integers(0, cfg.vocab_size, (B, L + 1))
batch = {"tokens": jnp.asarray(raw[:, :-1]), "labels": jnp.asarray(raw[:, 1:])}
tables = spion_dryrun_tables(cfg, L)

def timed(step):
    args = (params, opt, batch, jnp.int32(0), tables)
    jax.block_until_ready(step(*args)[2]["loss"])          # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(step(*args)[2]["loss"])
    return (time.perf_counter() - t0) / reps * 1e6

with mesh_context(mesh):
    auto_step = make_train_step(cfg, spion=True, sparse_kernel="auto")
    jaxpr = str(jax.make_jaxpr(auto_step)(params, opt, batch, jnp.int32(0),
                                          tables))
    assert "shard_map" in jaxpr and "pallas_call" in jaxpr, \
        "auto must resolve to the shard_map-fused kernel under the mesh"
    t_jnp = timed(jax.jit(make_train_step(cfg, spion=True,
                                          sparse_kernel="jnp")))
    t_fused = timed(jax.jit(auto_step))
print("ROW,sharded.auto_is_shard_map_fused,1,"
      "auto train-step jaxpr has shard_map+pallas_call (mesh data=2 model=2)")
print(f"ROW,sharded.train_step_jnp_us,{t_jnp:.1f},"
      "jnp BCSR gather path under GSPMD (4 virtual cpu devices)")
print(f"ROW,sharded.train_step_fused_us,{t_fused:.1f},"
      "shard_map-fused (Pallas interpreter on CPU: records the dispatch + "
      f"trajectory; TPU numbers are the speedup claim) jnp/fused="
      f"{t_jnp / t_fused:.2f}x")
"""


def _subprocess_rows(out, child, smoke):
    """Run a bench child on 4 fake host devices and collect its ROW lines
    (jax locks the device count at first init, so meshes that differ from
    the parent's need a fresh process)."""
    import os
    import pathlib
    import subprocess
    import sys

    root = str(pathlib.Path(__file__).resolve().parent.parent)
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
           "JAX_PLATFORMS": "cpu",
           "PYTHONPATH": "src" + os.pathsep + os.environ.get("PYTHONPATH", ""),
           "SPION_BENCH_L": "128" if smoke else "256",
           "SPION_BENCH_B": "4",
           "SPION_BENCH_REPS": "2" if smoke else "5"}
    r = subprocess.run([sys.executable, "-c", child],
                       capture_output=True, text=True, cwd=root, env=env,
                       timeout=900)
    if r.returncode != 0:
        raise RuntimeError(f"bench child failed:\n{r.stderr[-2000:]}")
    for line in r.stdout.splitlines():
        if line.startswith("ROW,"):
            _, name, value, derived = line.split(",", 3)
            out(name, float(value), derived)


def sharded_rows(out, smoke=False):
    """`sharded` mode: before/after train-step rows (jnp BCSR vs
    shard_map-fused) on a (data=2, model=2) virtual mesh. Runs in a
    subprocess because the fake device count must be set before jax
    initialises. On CPU the fused numbers go through the Pallas interpreter
    — the row pair documents the mesh dispatch and gives the trajectory a
    before/after anchor, not a CPU speedup claim."""
    _subprocess_rows(out, _SHARDED_CHILD, smoke)


# Child program for the `seqshard` mode: sparse train step on a
# (seq=2, data=2) virtual mesh — the sequence-parallel dispatch
# (DESIGN.md §10). Rows record the pattern halo, assert the ppermute halo
# exchange is in the step, and time the jnp path vs the seq-sharded fused
# kernel (Pallas interpreter on CPU: dispatch + trajectory anchor, not a
# CPU speedup claim).
_SEQSHARD_CHILD = r"""
import dataclasses, os, time
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_seq_mesh
from repro.launch.steps import make_train_step, spion_dryrun_tables
from repro.models.registry import build
from repro.optim import adamw_init

L = int(os.environ["SPION_BENCH_L"])
B = int(os.environ["SPION_BENCH_B"])
reps = int(os.environ["SPION_BENCH_REPS"])
mesh = make_seq_mesh(2, 2)
cfg = get_config("spion-lra").reduced()
cfg = cfg.replace(num_heads=4, num_kv_heads=2, head_dim=16,
                  spion=dataclasses.replace(cfg.spion, block_size=16))
bundle = build(cfg)
params = jax.tree_util.tree_map(
    lambda x: x.astype(jnp.float32) if x.ndim >= 2 else x,
    bundle.init(jax.random.key(0)))
opt = adamw_init(params)
rng = np.random.default_rng(0)
raw = rng.integers(0, cfg.vocab_size, (B, L + 1))
batch = {"tokens": jnp.asarray(raw[:, :-1]), "labels": jnp.asarray(raw[:, 1:])}
# bounded-extent pattern: the near-diagonal flood-fill shape seq sharding
# targets (the default global verticals would fall back by design)
tables = spion_dryrun_tables(cfg, L, max_extent=2)
h_l, h_r = tables["halo"]

def timed(step):
    args = (params, opt, batch, jnp.int32(0), tables)
    jax.block_until_ready(step(*args)[2]["loss"])          # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(step(*args)[2]["loss"])
    return (time.perf_counter() - t0) / reps * 1e6

with mesh_context(mesh):
    auto_step = make_train_step(cfg, spion=True, sparse_kernel="auto",
                                halo=tables["halo"])
    jaxpr = str(jax.make_jaxpr(auto_step)(params, opt, batch, jnp.int32(0),
                                          tables))
    assert "shard_map" in jaxpr and "pallas_call" in jaxpr and \
        "ppermute" in jaxpr, \
        "auto must resolve to the seq-sharded fused kernel under the mesh"
    t_jnp = timed(jax.jit(make_train_step(cfg, spion=True,
                                          sparse_kernel="jnp")))
    t_fused = timed(jax.jit(auto_step))
print(f"ROW,seqshard.halo_blocks,{h_l + h_r},"
      f"pattern col extent (left={h_l} right={h_r}) in blocks — the halo "
      "each shard exchanges with its neighbours")
print("ROW,seqshard.auto_is_seq_sharded,1,"
      "auto train-step jaxpr has shard_map+pallas_call+ppermute "
      "(mesh seq=2 data=2)")
print(f"ROW,seqshard.train_step_jnp_us,{t_jnp:.1f},"
      "jnp BCSR gather path under GSPMD (4 virtual cpu devices)")
print(f"ROW,seqshard.train_step_fused_us,{t_fused:.1f},"
      "seq-sharded fused (Pallas interpreter on CPU: records the dispatch + "
      f"trajectory; TPU numbers are the speedup claim) jnp/fused="
      f"{t_jnp / t_fused:.2f}x")
"""


def seqshard_rows(out, smoke=False):
    """`seqshard` mode: sparse train step on a (seq=2, data=2) virtual mesh
    — records the pattern halo and the jnp vs seq-sharded-fused train-step
    rows (subprocess; proves "auto" engages the pattern-bounded halo
    exchange on sequence-parallel meshes)."""
    _subprocess_rows(out, _SEQSHARD_CHILD, smoke)


def train_step_rows(out, L=512, D=32, block=32, density=0.12, smoke=False):
    """fwd+bwd timings: the training-speed claim, not the inference one."""
    import dataclasses

    from repro.core.sparse_attention import bcsr_attention
    from repro.kernels.block_sparse_attn import fused_block_sparse_attention
    from repro.launch.steps import make_train_step, spion_dryrun_tables
    from repro.models.registry import build
    from repro.optim import adamw_init

    if smoke:
        L, D = 128, 16
    B, H, KV = 2, 2, 2
    cfg = get_config("spion-lra").reduced().replace(
        num_heads=H, num_kv_heads=KV, head_dim=D, causal=False)
    cfg = cfg.replace(spion=dataclasses.replace(cfg.spion, block_size=block))
    key = jax.random.key(0)
    q = jax.random.normal(key, (B, L, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, L, KV, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, L, KV, D))
    n = L // block
    rng = np.random.default_rng(0)
    mask = rng.random((n, n)) < density
    np.fill_diagonal(mask, True)
    bcsr = bcsr_from_blockmask(mask, block)
    pos = jnp.arange(L)

    from repro.models import attention as A

    def dense_loss(q, k, v):
        return jnp.sum(A.dense_attention(cfg, q, k, v, pos, pos) ** 2)

    def sparse_jnp_loss(q, k, v):
        return jnp.sum(bcsr_attention(cfg, q, k, v, bcsr) ** 2)

    t_dense = _time(jax.jit(jax.value_and_grad(dense_loss, argnums=(0, 1, 2))),
                    q, k, v)
    t_sparse = _time(jax.jit(jax.value_and_grad(sparse_jnp_loss, argnums=(0, 1, 2))),
                     q, k, v)
    out("train_step.attn_dense_fwdbwd_us", round(t_dense, 1), "")
    out("train_step.attn_sparse_jnp_fwdbwd_us", round(t_sparse, 1),
        f"speedup={t_dense / t_sparse:.2f}x density={density}")

    from repro.kernels.dispatch import is_compiled_backend
    if is_compiled_backend():
        from repro.kernels.ops import _flatten_bk, _split_heads
        col = jnp.maximum(bcsr.col_idx, 0)
        qs, ks, vs, dims = _split_heads(q, k, v)
        qh, kh, vh = _flatten_bk(qs, ks, vs, dims)

        def fused_loss(q, k, v):
            o = fused_block_sparse_attention(q, k, v, col, bcsr.nvalid,
                                             block=block, causal=cfg.causal)
            return jnp.sum(o ** 2)

        t_fused = _time(jax.jit(jax.value_and_grad(fused_loss, argnums=(0, 1, 2))),
                        qh, kh, vh)
        out("train_step.attn_sparse_fused_fwdbwd_us", round(t_fused, 1),
            f"speedup={t_dense / t_fused:.2f}x (custom VJP Pallas bwd)")
    else:
        out("train_step.attn_sparse_fused_fwdbwd_us", 0,
            "skipped: non-compiled backend runs the Pallas interpreter "
            "(compiled lanes: TPU Mosaic, GPU Triton)")

    # SparsityPlan before/after (any backend; Pallas interpreter on CPU):
    # fused fwd+bwd where the backward either rebuilds the transposed tables
    # under jit at KT = nrb (before) or consumes the host-built plan tables
    # at KT* (after), on the skewed sliding-window + global-stripe pattern.
    Lp = 128 if smoke else 256
    blkp = 16
    plan, col_st, nv_st = _skewed_pattern_plan(Lp, blkp)
    nrb_p = Lp // blkp
    key = jax.random.key(7)
    Np, Gp, hdp = 2, 1, 32
    qp = jax.random.normal(key, (Np, Gp, Lp, hdp))
    kp = jax.random.normal(jax.random.fold_in(key, 1), (Np, Lp, hdp))
    vp = jax.random.normal(jax.random.fold_in(key, 2), (Np, Lp, hdp))
    colp = jnp.maximum(plan.tables["col_idx"][1], 0)
    nvp = plan.tables["nvalid"][1]

    def loss_transpose(q, k, v):
        o = fused_block_sparse_attention(q, k, v, colp, nvp, block=blkp,
                                         causal=True)
        return jnp.sum(o ** 2)

    def loss_plan(q, k, v):
        o = fused_block_sparse_attention(
            q, k, v, colp, nvp, block=blkp, causal=True,
            row_idx=plan.tables["row_idx"][1],
            nvalid_t=plan.tables["nvalid_t"][1])
        return jnp.sum(o ** 2)

    reps = 3 if smoke else 5
    t_before = _time(jax.jit(jax.value_and_grad(loss_transpose,
                                                argnums=(0, 1, 2))),
                     qp, kp, vp, reps=reps)
    t_after = _time(jax.jit(jax.value_and_grad(loss_plan, argnums=(0, 1, 2))),
                    qp, kp, vp, reps=reps)
    out("train_step.attn_fused_bwd_transpose_us", round(t_before, 1),
        f"before: under-jit bcsr_transpose, dK/dV grid width nrb={nrb_p}")
    out("train_step.attn_fused_bwd_plan_us", round(t_after, 1),
        f"after: SparsityPlan, dK/dV grid width KT*={plan.kt_star} "
        f"speedup={t_before / t_after:.2f}x")

    # full optimizer step: dense phase vs sparse phase (jnp kernel — the
    # phase switch itself is what's being costed on CPU)
    bundle = build(cfg)
    params = bundle.init(jax.random.key(1))
    params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.ndim >= 2 else x, params)
    opt = adamw_init(params)
    raw = rng.integers(0, cfg.vocab_size, (B, L + 1))
    batch = {"tokens": jnp.asarray(raw[:, :-1]), "labels": jnp.asarray(raw[:, 1:])}
    tables = spion_dryrun_tables(cfg, L)
    dense_step = jax.jit(make_train_step(cfg))
    sparse_step = jax.jit(make_train_step(cfg, spion=True, sparse_kernel="jnp"))
    reps = 2 if smoke else 5
    td = _time(lambda p, o, b: dense_step(p, o, b, jnp.int32(0))[2]["loss"],
               params, opt, batch, reps=reps)
    ts = _time(lambda p, o, b: sparse_step(p, o, b, jnp.int32(0), tables)[2]["loss"],
               params, opt, batch, reps=reps)
    out("train_step.model_dense_us", round(td, 1), "")
    out("train_step.model_sparse_us", round(ts, 1),
        f"speedup={td / ts:.2f}x seq={L} reduced-arch")


def serve_rows(out, smoke=False):
    """`serve` mode: the train->serve story in numbers.

    (a) continuous-batching engine throughput: fused-prefill tokens/s and
        batched decode tokens/s on a reduced arch;
    (b) the sparse-decode claim: jitted decode_step dense vs sparse
        (pattern-bounded cache-block gather) at S_cache in {1k, 4k} — the
        gather reads K*block positions instead of the whole cache, so the
        win must GROW with cache length and show at >= 4k even on CPU.
    """
    from repro.configs import get_config
    from repro.core.attention_exec import SparseAttentionExec
    from repro.launch.serve import Request, ServeEngine
    from repro.launch.steps import make_serve_step
    from repro.models.registry import build

    cfg = get_config("qwen2-7b").reduced().replace(remat=False)
    bundle = build(cfg)
    params = bundle.init(jax.random.key(0))
    rng = np.random.default_rng(0)

    # (a) engine throughput: prefill, then pure decode ticks
    P, max_new, slots = 64, 8, 4
    eng = ServeEngine(cfg, params, slots=slots, max_len=256)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, P).astype(np.int32),
                    max_new=max_new) for i in range(slots)]
    for r in reqs:
        eng.submit(r)
    eng.step()                                    # warm-up: prefill + 1 tick
    n0 = sum(len(r.out) for r in reqs)
    t0 = time.perf_counter()
    eng.run([])                                   # drain remaining decode ticks
    dt_dec = time.perf_counter() - t0
    gen = sum(len(r.out) for r in reqs) - n0      # tokens in the timed window
    eng2 = ServeEngine(cfg, params, slots=1, max_len=256)
    warm = rng.integers(0, cfg.vocab_size, P).astype(np.int32)
    eng2.run([Request(rid=0, prompt=warm, max_new=1)])       # compile prefill
    t0 = time.perf_counter()
    eng2.run([Request(rid=1, prompt=warm.copy(), max_new=1)])
    dt_pref = time.perf_counter() - t0
    out("serve.prefill_tok_s", round(P / max(dt_pref, 1e-9), 1),
        f"fused prefill, P={P}")
    out("serve.engine_decode_tok_s", round(gen / max(dt_dec, 1e-9), 1),
        f"{slots} slots, per-slot positions")

    # (b) dense vs sparse decode at growing cache lengths. Donate the cache
    # exactly as the engine's jitted decode does — without donation every
    # call pays a full functional cache copy that is identical for both
    # paths and drowns the read-less-cache signal this row exists to show.
    # min-of-reps timing: robust to noisy-neighbour CPU on CI runners.
    block, width, B = 32, 8, 4
    dense_step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    sparse_step = jax.jit(make_serve_step(cfg, spion=True),
                          donate_argnums=(1,))
    reps = 5 if smoke else 20

    def timed_decode(step, S, *extra):
        cache = bundle.init_cache(B, S)
        tok = jnp.zeros((B, 1), jnp.int32)
        pos = jnp.full((B,), S - 1, jnp.int32)    # full-cache worst case
        logits, cache = step(params, cache, tok, pos, *extra)   # compile
        jax.block_until_ready(logits)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            logits, cache = step(params, cache, tok, pos, *extra)
            jax.block_until_ready(logits)
            best = min(best, time.perf_counter() - t0)
        return best * 1e6

    speedups = {}
    for S in (1024, 4096):
        from repro.launch.steps import causal_band_tables
        tabs = causal_band_tables(cfg.num_layers, S // block, width=width)
        ex = SparseAttentionExec(
            {k: jnp.asarray(v) for k, v in tabs.items()},
            block=block, phase="decode")
        td = timed_decode(dense_step, S)
        ts = timed_decode(sparse_step, S, ex)
        tag = f"{S // 1024}k"
        speedups[S] = td / ts
        out(f"serve.decode_dense_us_{tag}", round(td, 1), f"S_cache={S}")
        out(f"serve.decode_sparse_us_{tag}", round(ts, 1),
            f"speedup={td / ts:.2f}x K*block={width * block} of {S}")
    out("serve.decode_sparse_speedup_4k", round(speedups[4096], 2),
        f"vs {speedups[1024]:.2f}x at 1k — the win grows with S_cache")

    # (c) the paged-cache claim (DESIGN.md §14): decode throughput paged vs
    # contiguous at matched slot counts, pool-vs-contiguous memory at 64
    # slots (the paged pool sizes to the WORST-CASE PAGE BUDGET of the
    # actual requests, not slots*max_len — that accounting gap is what lets
    # the paged engine run 64 concurrent slots where a contiguous cache
    # would allocate the full rectangle), and prefix-sharing telemetry on a
    # shared-system-prompt workload.
    P2, new2, SL = 16, 8, 256

    def engine_decode_tok_s(paged, slots, **kw):
        e = ServeEngine(cfg, params, slots=slots, max_len=SL, paged=paged,
                        **kw)
        rs = [Request(rid=i,
                      prompt=rng.integers(0, cfg.vocab_size,
                                          P2).astype(np.int32),
                      max_new=new2) for i in range(slots)]
        for r in rs:
            e.submit(r)
        e.step()                      # admit-all + first tick (compile)
        n0 = sum(len(r.out) for r in rs)
        t0 = time.perf_counter()
        e.run([])
        dt = time.perf_counter() - t0
        return (sum(len(r.out) for r in rs) - n0) / max(dt, 1e-9), e

    tp16p, ep16 = engine_decode_tok_s(True, 16)
    tp16c, _ = engine_decode_tok_s(False, 16)
    out("serve.contig_decode_tok_s_16", round(tp16c, 1),
        f"16 slots, P={P2}, max_new={new2}")
    out("serve.paged_decode_tok_s_16", round(tp16p, 1),
        f"ratio={tp16p / max(tp16c, 1e-9):.2f}x vs contiguous, "
        f"page={ep16.page}")
    budget64 = 64 * -(-(P2 + new2) // ep16.page) + 1   # worst case + scratch
    tp64, e64 = engine_decode_tok_s(True, 64, num_pages=budget64)
    pool_b = e64.pool.nbytes
    # the contiguous rectangle the same 64 slots would have to allocate
    cdt = jnp.dtype(cfg.cache_dtype or cfg.dtype)
    contig_b = (2 * cfg.num_layers * 64 * SL * cfg.num_kv_heads
                * cfg.resolved_head_dim * cdt.itemsize)
    out("serve.paged_decode_tok_s_64", round(tp64, 1),
        f"64 slots from a {budget64}-page pool "
        f"({budget64 * ep16.page} positions vs contiguous {64 * SL})")
    out("serve.paged_pool_mib_64", round(pool_b / 2**20, 3),
        f"{budget64} pages x {ep16.page}")
    out("serve.contig_cache_mib_64", round(contig_b / 2**20, 3),
        f"64 slots x max_len={SL} rectangle")
    out("serve.paged_mem_ratio_64", round(contig_b / pool_b, 2),
        "contiguous bytes / pool bytes at 64 slots")

    # shared system prompt: 3 requests, 64-token common prefix
    sys_p = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
    esh = ServeEngine(cfg, params, slots=2, max_len=SL, paged=True)
    share = [Request(rid=i,
                     prompt=np.concatenate(
                         [sys_p, rng.integers(0, cfg.vocab_size,
                                              5).astype(np.int32)]),
                     max_new=4) for i in range(3)]
    esh.run(share)
    st = esh.prefix_stats
    out("serve.prefix_hit_rate", round(st["prefix_hit_rate"], 3),
        f"{st['hits']}/{st['lookups']} page lookups hit; "
        f"{st['prefix_tokens_reused']} prompt tokens reused")
    out("serve.prefix_prefill_fused_calls", st["prefill_fused"],
        "3 shared-prefix requests -> the prefix prefilled once")
