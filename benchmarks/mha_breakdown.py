"""Fig. 6: breakdown of MHA operation times — dense GEMM/softmax/GEMM vs
sparse SDDMM/sparse-softmax/SpMM.

CPU wall-times of the jitted jnp paths (the GPU numbers in the paper are
hardware-specific; the *structure* — softmax dominating dense MHA, every
sparse op beating its dense counterpart at 90%+ sparsity — is what this
reproduces). Derived column reports op-count ratios from §4.4.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.sparse_attention import bcsr_from_blockmask
from repro.kernels import ref as kref


def _time(f, *args, reps=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def rows(out, L=1024, D=64, block=32, density=0.08):
    B, H = 2, 2
    N = B * H
    key = jax.random.key(0)
    q = jax.random.normal(key, (N, L, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (N, L, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (N, L, D))
    rng = np.random.default_rng(0)
    n = L // block
    mask = rng.random((n, n)) < density
    np.fill_diagonal(mask, True)
    bcsr = bcsr_from_blockmask(mask, block)
    col = jnp.maximum(bcsr.col_idx, 0)

    # dense pipeline
    gemm1 = jax.jit(lambda q, k: jnp.einsum("nqd,nkd->nqk", q, k) / np.sqrt(D))
    soft = jax.jit(lambda s: jax.nn.softmax(s, -1))
    gemm2 = jax.jit(lambda p, v: jnp.einsum("nqk,nkd->nqd", p, v))
    s_dense = gemm1(q, k)
    p_dense = soft(s_dense)
    t_gemm1 = _time(gemm1, q, k)
    t_soft = _time(soft, s_dense)
    t_gemm2 = _time(gemm2, p_dense, v)

    # sparse pipeline (jnp reference path of the kernels)
    sddmm = jax.jit(lambda q, k: kref.sddmm_ref(q, k, bcsr.col_idx, block=block))
    s_sp = sddmm(q, k)
    ssoft = jax.jit(lambda s: kref.sparse_softmax_ref(s, bcsr.col_idx,
                                                      block=block, seq_len=L))
    p_sp = ssoft(s_sp)
    spmm = jax.jit(lambda p, v: kref.spmm_ref(p, v, bcsr.col_idx))
    t_sddmm = _time(sddmm, q, k)
    t_ssoft = _time(ssoft, s_sp)
    t_spmm = _time(spmm, p_sp, v)

    out("mha.dense_gemm_qk_us", round(t_gemm1, 1), "")
    out("mha.dense_softmax_us", round(t_soft, 1), "")
    out("mha.dense_gemm_av_us", round(t_gemm2, 1), "")
    out("mha.sparse_sddmm_us", round(t_sddmm, 1),
        f"speedup={t_gemm1 / t_sddmm:.2f}x (paper: 2.55x image)")
    out("mha.sparse_softmax_us", round(t_ssoft, 1),
        f"speedup={t_soft / t_ssoft:.2f}x (paper: 42.4x image)")
    out("mha.sparse_spmm_us", round(t_spmm, 1),
        f"speedup={t_gemm2 / t_spmm:.2f}x (paper: 2.54x image)")
    tot_d = t_gemm1 + t_soft + t_gemm2
    tot_s = t_sddmm + t_ssoft + t_spmm
    out("mha.total_speedup", round(tot_d / tot_s, 2),
        f"density={density} dense={tot_d:.0f}us sparse={tot_s:.0f}us")
