"""Fault-recovery bench: training throughput before a kill vs after a
resume, on a REAL 2-process `jax.distributed` CPU job — plus the cost of a
divergence rollback (DESIGN.md §13).

Four legs driving tests/mp_train_worker.py (the same harness the
tier1-multiprocess suite uses); the first three share one checkpoint dir:

  1. uninterrupted 2-process run through the dense->sparse transition
     (commits checkpoints along the way)           -> `before_kill` row
  2. restart that is SIGKILLed mid-sparse-phase (the orphaned survivor is
     reaped by the harness, as a real job supervisor would)
  3. restart after the kill: restores the last committed step, digest-checks
     the restored plan, trains on                  -> `after_resume` row
  4. fresh run with chaos NaN-poisoning the params at step 9: the sentinel
     rolls back to the pinned good checkpoint, skips the data window, and
     replays to the target                          -> `rollback` row
     (us/step over the whole leg, replay included) and
     `rollback_recovery_us` (quarantine + restore + skip wall time, from
     the structured SPION_EVENT the rollback emits)

Values are us/step over each completed leg (jit compile and — for legs 3/4
— checkpoint restore included: these rows are recovery health, not kernel
perf). CI's bench-smoke job asserts all four rows exist and are error-free
like any other row.
"""
from __future__ import annotations

import json
import os
import re
import signal
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_WORKER = os.path.join("tests", "mp_train_worker.py")


def _free_port():
    import socket
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def _spawn(nproc, port, ckpt_dir, target, *, ckpt_every=3, chaos=None,
           chaos_pid=None):
    procs = []
    for pid in range(nproc):
        env = {"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
               "PATH": os.environ.get("PATH", "/usr/bin:/bin")}
        if chaos and pid == chaos_pid:
            env.update(chaos)
        procs.append(subprocess.Popen(
            [sys.executable, _WORKER, "--pid", str(pid),
             "--nproc", str(nproc), "--port", str(port),
             "--ckpt-dir", ckpt_dir, "--target-step", str(target),
             "--ckpt-every", str(ckpt_every)],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            env=env, cwd=_ROOT))
    return procs


def _drain(procs, timeout=900):
    outs = []
    try:
        for p in procs:
            out, err = p.communicate(timeout=timeout)
            outs.append((p.returncode, out, err))
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    return outs


def _timing(stdout):
    m = re.search(r"WORKER_TIMING steps=(\d+) seconds=([\d.]+)", stdout)
    if not m:
        raise RuntimeError(f"no WORKER_TIMING in worker output:\n{stdout}")
    return int(m.group(1)), float(m.group(2))


def rows(out, smoke=False):
    import tempfile
    with tempfile.TemporaryDirectory() as ckpt_dir:
        # leg 1: uninterrupted to step 8 (dense 0-7, transition at 8 via
        # steps_per_epoch=4 + max_dense_epochs=2); commits 3, 6, 8
        outs = _drain(_spawn(2, _free_port(), ckpt_dir, 8))
        if any(rc != 0 for rc, _, _ in outs):
            raise RuntimeError(f"before-kill leg failed:\n{outs[0][2][-2000:]}")
        steps, secs = _timing(outs[0][1])
        out("faultrecovery.before_kill", secs / steps * 1e6,
            f"{steps / secs:.2f} steps/s (2 procs; compile incl)")

        # leg 2: resume and SIGKILL process 1 mid-sparse-phase at step 12
        procs = _spawn(2, _free_port(), ckpt_dir, 16,
                       chaos={"SPION_CHAOS_KILL_STEP": "12",
                              "SPION_CHAOS_KILL_PROC": "1",
                              "SPION_CHAOS_SIGNAL": "KILL"}, chaos_pid=1)
        procs[1].wait(timeout=900)
        if procs[1].returncode != -signal.SIGKILL:
            raise RuntimeError(
                f"chaos victim exited {procs[1].returncode}, expected SIGKILL")
        procs[0].kill()  # survivor is wedged in a dead collective
        _drain(procs, timeout=60)

        # leg 3: restart restores the last committed step and trains on
        outs = _drain(_spawn(2, _free_port(), ckpt_dir, 16))
        if any(rc != 0 for rc, _, _ in outs):
            raise RuntimeError(f"resume leg failed:\n{outs[0][2][-2000:]}")
        if "phase=sparse" not in outs[0][1]:
            raise RuntimeError("resume leg did not end in the sparse phase")
        first = min(int(m.group(1)) for m in
                    re.finditer(r"^LOSS,(\d+),", outs[0][1], re.M))
        steps, secs = _timing(outs[0][1])
        out("faultrecovery.after_resume", secs / steps * 1e6,
            f"{steps / secs:.2f} steps/s (restore+compile incl; "
            f"resumed@{first})")

    # leg 4: divergence rollback — single process, NaN-poisoned params at
    # step 9 (checkpoints every 3): sentinel detects the non-finite loss,
    # quarantines, restores the pinned good step, skips the window, replays
    with tempfile.TemporaryDirectory() as roll_dir:
        outs = _drain(_spawn(1, _free_port(), roll_dir, 12,
                             chaos={"SPION_CHAOS_NAN_STEP": "9"},
                             chaos_pid=0))
        if any(rc != 0 for rc, _, _ in outs):
            raise RuntimeError(f"rollback leg failed:\n{outs[0][2][-2000:]}")
        ev = None
        for m in re.finditer(r"^SPION_EVENT (\{.*\})$", outs[0][1], re.M):
            cand = json.loads(m.group(1))
            if cand.get("event") == "rollback":
                ev = cand
        if ev is None:
            raise RuntimeError(
                f"rollback leg emitted no rollback event:\n{outs[0][1]}")
        steps, secs = _timing(outs[0][1])
        out("faultrecovery.rollback", secs / steps * 1e6,
            f"{steps / secs:.2f} steps/s (NaN@9 -> rolled back to step "
            f"{ev['to_step']}, skipped {ev['skip']} data steps, replay incl)")
        out("faultrecovery.rollback_recovery_us", ev["seconds"] * 1e6,
            "quarantine + pinned-checkpoint restore + data-window skip")
