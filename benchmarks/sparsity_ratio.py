"""Fig. 7: training time + accuracy proxy vs sparsity ratio (SPION-C,
ListOps geometry). Wall-time per train step of the sparse path at each ratio,
plus the §4.4 op-count at that ratio (derived column)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.pattern import generate_pattern
from repro.core.sparse_attention import bcsr_from_blockmask
from repro.launch.steps import make_train_step
from repro.models.registry import build
from repro.optim import adamw_init
from benchmarks.opcount import dense_ops, sparse_ops


def rows(out, L=512, block=32):
    cfg = get_config("spion-lra").replace(num_layers=2, d_ff=128)
    bundle = build(cfg)
    params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.ndim >= 2 else x,
        bundle.init(jax.random.key(0)))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, spion=True, block=block))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 100, (4, L)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 100, (4, L)), jnp.int32)}
    n = L // block
    scores = rng.random((L, L))

    for alpha in (0.70, 0.80, 0.90, 0.96, 0.98):
        pat = generate_pattern(scores, variant="c", block_size=block,
                               alpha_quantile=alpha)
        K = int(pat.sum(1).max())
        b = bcsr_from_blockmask(pat, block, max_k=K)
        tables = {"col_idx": jnp.stack([b.col_idx] * cfg.num_layers),
                  "nvalid": jnp.stack([b.nvalid] * cfg.num_layers),
                  "block": block}
        p2, o2, m = step(params, opt, batch, jnp.int32(0), tables)
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for i in range(3):
            p2, o2, m = step(params, opt, batch, jnp.int32(i), tables)
        jax.block_until_ready(m["loss"])
        us = (time.perf_counter() - t0) / 3 * 1e6
        C = int(pat.mean() * L * L)
        out(f"sparsity.alpha{int(alpha*100)}_step_us", round(us, 0),
            f"density={pat.mean():.3f} opcount_reduction="
            f"{dense_ops(L,64)/max(sparse_ops(C,L,64),1):.2f}x")
