"""Kernel-bench trajectory regression gate (CI).

Reads a BENCH_*.json trajectory (a list of run entries, each with `rows`)
and fails if the LATEST entry regressed against the history on the gated
kernel rows:

  *_us rows (lower is better)           latest <= factor * median(history)
  *.pct_of_peak rows (higher is better) latest >= median(history) / factor

`factor` defaults to 3.0 — wall clocks in the committed trajectory span
different machines (dev boxes, CI runners), so the gate catches step-change
regressions (an accidentally serialized DMA ring, a grid that stopped
shrinking), not single-digit-percent noise. Override with
SPION_BENCH_GATE_FACTOR or --factor. Rows with fewer than 2 historical
samples pass trivially (a fresh row has no baseline yet).

Usage: python benchmarks/check_regression.py [BENCH_smoke.json] [--factor F]
Exit 0 = no regression, 1 = regression, 2 = unusable trajectory.
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys

# the gated rows: the compiled-lane kernel trajectory. Serving/engine
# throughputs and model-level steps are intentionally NOT gated — they mix
# too much non-kernel machinery to hold a cross-machine line.
GATED_PREFIXES = ("bwd.dq_us", "bwd.dkv_padded_us", "bwd.dkv_plan_us",
                  "train_step.attn_fused_bwd_transpose_us",
                  "train_step.attn_fused_bwd_plan_us",
                  "roofline.fused_fwd.pct_of_peak",
                  "roofline.fused_dq.pct_of_peak",
                  "roofline.fused_dkv.pct_of_peak")


def _series(hist):
    """row name -> list of values across trajectory entries, in order."""
    out = {}
    for entry in hist:
        for r in entry.get("rows", []):
            out.setdefault(r["name"], []).append(r["value"])
    return out


def check(path: str, factor: float) -> int:
    try:
        with open(path) as f:
            hist = json.load(f)
        if not isinstance(hist, list) or not hist:
            raise ValueError("trajectory is not a non-empty list")
    except (OSError, json.JSONDecodeError, ValueError) as e:
        print(f"check_regression: unusable trajectory {path}: {e}",
              file=sys.stderr)
        return 2
    series = _series(hist)
    failures = []
    for name in sorted(series):
        if not name.startswith(GATED_PREFIXES):
            continue
        vals = [float(v) for v in series[name]]
        *prior, latest = vals
        if len(prior) < 1:
            print(f"  pass  {name}: first sample ({latest}) — no baseline")
            continue
        base = statistics.median(prior)
        if name.endswith(".pct_of_peak"):
            ok, cmp = latest >= base / factor, f">= {base / factor:.4g}"
        else:
            ok, cmp = latest <= base * factor, f"<= {base * factor:.4g}"
        status = "pass" if ok else "FAIL"
        print(f"  {status}  {name}: latest={latest:.4g} "
              f"median({len(prior)} prior)={base:.4g} need {cmp}")
        if not ok:
            failures.append(name)
    if failures:
        print(f"check_regression: {len(failures)} gated row(s) regressed "
              f"beyond {factor}x: {failures}", file=sys.stderr)
        return 1
    print(f"check_regression: OK ({path}, factor={factor})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", nargs="?", default="BENCH_smoke.json")
    ap.add_argument("--factor", type=float,
                    default=float(os.environ.get("SPION_BENCH_GATE_FACTOR",
                                                 3.0)))
    args = ap.parse_args(argv)
    return check(args.path, args.factor)


if __name__ == "__main__":
    sys.exit(main())
