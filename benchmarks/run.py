"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract, and
appends every run's rows to a ``BENCH_*.json`` trajectory file (a JSON list
of {argv, smoke, unix_time, rows} entries) so successive runs/PRs build a
perf history that CI uploads as an artifact.

  opcount          §4.4 exact op-count identities (Table-in-text)
  mha_breakdown    Fig. 6 dense vs sparse MHA op times
  train_step       fwd+bwd (training) timings through the differentiable
                   fused kernel path — the paper's actual headline claim —
                   incl. SparsityPlan vs per-step-transpose before/after
  bwd              dQ vs dK/dV backward-kernel split; asserts the dK/dV
                   grid width equals the SparsityPlan's KT*
  sharded          sparse train step on a 4-virtual-device (data x model)
                   mesh: jnp BCSR vs shard_map-fused before/after rows
                   (subprocess; proves "auto" keeps the kernel on meshes)
  seqshard         sparse train step on a (seq=2, data=2) mesh: the
                   sequence-parallel halo-exchange dispatch — halo width,
                   ppermute proof, jnp vs seq-sharded-fused rows
  serve            continuous-batching engine throughput (fused prefill +
                   per-slot-position decode tokens/s) and dense-vs-sparse
                   decode_step at S_cache in {1k, 4k} — the pattern-bounded
                   cache gather must beat dense at >= 4k
  faultrecovery    steps/s before a mid-sparse-phase SIGKILL vs after the
                   checkpoint-restore resume, on a real 2-process
                   jax.distributed CPU job, plus the divergence-rollback leg
                   (NaN-poisoned step -> quarantine + pinned-checkpoint
                   restore + replay) — recovery health, not kernel perf
  autotune         compiled-lane autotuner: candidate sweep -> on-disk
                   cache -> SparseAttentionExec pickup (cache_hit row) with
                   the bitwise tuned-vs-default identity asserted
  roofline_kernels measured %-of-roofline per fused kernel (fwd/dQ/dK,dV)
                   vs per-backend peaks (SPION_PEAK_FLOPS/_BYTES_S) — the
                   per-kernel trajectory CI gates with check_regression.py
  sparsity_ratio   Fig. 7 step time vs sparsity ratio
  memory_footprint Fig. 5 memory column
  accuracy_proxy   Table 2 convergence proxy (generated ListOps)
  roofline         §Roofline table from the dry-run artifacts

``--smoke`` runs a fast subset at reduced sizes (CI); ``--only NAME`` (or a
bare positional NAME, back-compat) selects one module.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time
import traceback
from types import SimpleNamespace

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    # `python benchmarks/run.py` puts benchmarks/ (not the repo root) on
    # sys.path; make the script runnable from anywhere, installed or not
    if _p not in sys.path:
        sys.path.insert(0, _p)


def _parse_args(argv):
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("name", nargs="?", default=None,
                    help="run only this module (back-compat positional)")
    ap.add_argument("--only", default=None, help="run only this module")
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset at reduced sizes (CI smoke job)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="trajectory file to append to "
                         "(default: BENCH_smoke.json under --smoke, else "
                         "BENCH_trajectory.json, in the repo root)")
    return ap.parse_args(argv)


def _mods(smoke):
    from benchmarks import (accuracy_proxy, autotune_bench, fault_recovery,
                            memory_footprint, mha_breakdown, opcount,
                            roofline, sparsity_ratio)
    faultrecovery = SimpleNamespace(
        rows=functools.partial(fault_recovery.rows, smoke=smoke))
    autotune = SimpleNamespace(
        rows=functools.partial(autotune_bench.rows, smoke=smoke))
    roofline_kernels = SimpleNamespace(
        rows=functools.partial(roofline.kernel_rows, smoke=smoke))
    train_step = SimpleNamespace(
        rows=functools.partial(mha_breakdown.train_step_rows, smoke=smoke))
    bwd = SimpleNamespace(
        rows=functools.partial(mha_breakdown.bwd_rows, smoke=smoke))
    sharded = SimpleNamespace(
        rows=functools.partial(mha_breakdown.sharded_rows, smoke=smoke))
    seqshard = SimpleNamespace(
        rows=functools.partial(mha_breakdown.seqshard_rows, smoke=smoke))
    serve = SimpleNamespace(
        rows=functools.partial(mha_breakdown.serve_rows, smoke=smoke))
    if smoke:
        breakdown = SimpleNamespace(
            rows=functools.partial(mha_breakdown.rows, L=256))
        return [("opcount", opcount), ("mha_breakdown", breakdown),
                ("train_step", train_step), ("bwd", bwd),
                ("sharded", sharded), ("seqshard", seqshard),
                ("serve", serve), ("autotune", autotune),
                ("roofline_kernels", roofline_kernels),
                ("faultrecovery", faultrecovery)]
    return [("opcount", opcount), ("mha_breakdown", mha_breakdown),
            ("train_step", train_step), ("bwd", bwd), ("sharded", sharded),
            ("seqshard", seqshard), ("serve", serve),
            ("autotune", autotune),
            ("roofline_kernels", roofline_kernels),
            ("faultrecovery", faultrecovery),
            ("sparsity_ratio", sparsity_ratio),
            ("memory_footprint", memory_footprint),
            ("accuracy_proxy", accuracy_proxy), ("roofline", roofline)]


def _append_trajectory(path, entry):
    hist = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                hist = json.load(f)
            if not isinstance(hist, list):
                hist = [hist]
        except (json.JSONDecodeError, OSError):
            # never silently overwrite accumulated history: keep the corrupt
            # file aside and start a fresh trajectory
            bak = path + ".bak"
            os.replace(path, bak)
            print(f"# warning: unreadable trajectory moved to {bak}",
                  file=sys.stderr)
    hist.append(entry)
    with open(path, "w") as f:
        json.dump(hist, f, indent=1)


def main(argv=None) -> None:
    args = _parse_args(sys.argv[1:] if argv is None else argv)
    only = args.only or args.name
    rows = []
    print("name,us_per_call,derived")

    def out(name, value, derived=""):
        print(f"{name},{value},{derived}", flush=True)
        rows.append({"name": name, "value": value, "derived": derived})

    mods = _mods(args.smoke)
    if only and only not in [n for n, _ in mods]:
        have = ", ".join(n for n, _ in mods)
        print(f"error: unknown module {only!r}"
              + (" in --smoke mode" if args.smoke else "")
              + f"; have: {have}", file=sys.stderr)
        sys.exit(2)
    for name, mod in mods:
        if only and name != only:
            continue
        try:
            mod.rows(out)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc(file=sys.stderr)
            out(f"{name}.ERROR", 0, str(e)[:120])

    if only and not args.json:
        # partial runs are debugging aids; appending their incomplete row
        # sets would pollute the perf history (pass --json to force)
        print("# partial run (--only): trajectory not appended", file=sys.stderr)
        return
    default_json = "BENCH_smoke.json" if args.smoke else "BENCH_trajectory.json"
    path = args.json or os.path.join(_ROOT, default_json)
    _append_trajectory(path, {"argv": sys.argv[1:] if argv is None else argv,
                              "smoke": bool(args.smoke),
                              "unix_time": time.time(), "rows": rows})
    print(f"# trajectory appended -> {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
