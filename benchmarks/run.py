"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract.

  opcount          §4.4 exact op-count identities (Table-in-text)
  mha_breakdown    Fig. 6 dense vs sparse MHA op times
  sparsity_ratio   Fig. 7 step time vs sparsity ratio
  memory_footprint Fig. 5 memory column
  accuracy_proxy   Table 2 convergence proxy (generated ListOps)
  roofline         §Roofline table from the dry-run artifacts
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (accuracy_proxy, memory_footprint, mha_breakdown,
                            opcount, roofline, sparsity_ratio)
    mods = [("opcount", opcount), ("mha_breakdown", mha_breakdown),
            ("sparsity_ratio", sparsity_ratio),
            ("memory_footprint", memory_footprint),
            ("accuracy_proxy", accuracy_proxy), ("roofline", roofline)]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")

    def out(name, value, derived=""):
        print(f"{name},{value},{derived}", flush=True)

    for name, mod in mods:
        if only and name != only:
            continue
        try:
            mod.rows(out)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc(file=sys.stderr)
            out(f"{name}.ERROR", 0, str(e)[:120])


if __name__ == "__main__":
    main()
