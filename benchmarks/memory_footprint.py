"""Fig. 5 memory column: training-step memory footprint, dense vs SPION
sparse, from compiled memory_analysis on the host device (byte-exact
accounting of the attention intermediates, paper: 4.6-9.6x reduction)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.sparse_attention import bcsr_from_blockmask
from repro.kernels import ref as kref
from repro.models import attention as A


def _mem(f, *args):
    c = jax.jit(f).lower(*args).compile()
    m = c.memory_analysis()
    return (getattr(m, "temp_size_in_bytes", 0) +
            getattr(m, "output_size_in_bytes", 0))


def rows(out, L=1024, D=64, block=32, density=0.06):
    N = 4
    q = jax.ShapeDtypeStruct((N, L, D), jnp.float32)
    rng = np.random.default_rng(0)
    n = L // block
    mask = rng.random((n, n)) < density
    np.fill_diagonal(mask, True)
    b = bcsr_from_blockmask(mask, block)

    dense = _mem(lambda q, k, v: jnp.einsum(
        "nqk,nkd->nqd", jax.nn.softmax(
            jnp.einsum("nqd,nkd->nqk", q, k) / np.sqrt(D), -1), v), q, q, q)
    sparse = _mem(lambda q, k, v: kref.spmm_ref(
        kref.sparse_softmax_ref(
            kref.sddmm_ref(q, k, b.col_idx, block=block), b.col_idx,
            block=block, seq_len=L), v, b.col_idx), q, q, q)
    out("memory.dense_mha_bytes", dense, "")
    out("memory.sparse_mha_bytes", sparse,
        f"reduction={dense/max(sparse,1):.2f}x (paper: 4.6-9.6x) density={density}")
