"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts."""
import glob
import json
import os
import sys

ARTS = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCHS = ["internvl2-2b", "whisper-tiny", "qwen2.5-14b", "mistral-large-123b",
         "command-r-35b", "qwen2-7b", "rwkv6-7b", "mixtral-8x7b",
         "arctic-480b", "zamba2-1.2b"]


def load(arch, shape, mesh, mode):
    p = os.path.join(ARTS, f"{arch}__{shape}__{mesh}__{mode}.json")
    if not os.path.exists(p):
        return None
    return json.load(open(p))


def fmt_cell(c):
    if c is None:
        return "—"
    if c["status"] == "skipped":
        return "skip"
    if c["status"] == "error":
        return "ERR"
    return "ok"


def dryrun_table():
    print("### Compile matrix (ok = lower+compile succeeded; bytes/device from memory_analysis)\n")
    print("| arch | " + " | ".join(f"{s} (single / multi)" for s in SHAPES) + " |")
    print("|---|" + "---|" * len(SHAPES))
    for a in ARCHS:
        row = [a]
        for s in SHAPES:
            cs = load(a, s, "single", "dense")
            cm = load(a, s, "multi", "dense")
            lab = fmt_cell(cs)
            if cs and cs["status"] == "ok":
                lab += f" {cs['memory'].get('per_device_gb', float('nan')):.1f}G"
            lab += " / " + fmt_cell(cm)
            if cm and cm["status"] == "ok":
                lab += f" {cm['memory'].get('per_device_gb', float('nan')):.1f}G"
            row.append(lab)
        print("| " + " | ".join(row) + " |")
    print()


def roofline_table(mode):
    print(f"### Roofline — {mode} (per-device terms in ms; dominant in bold)\n")
    print("| arch | shape | t_comp | t_mem | t_coll | bound | useful | mem GiB | n_micro |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in SHAPES:
            c = load(a, s, "single", mode)
            if c is None or c["status"] != "ok" or "roofline" not in c:
                continue
            r = c["roofline"]
            dom = c["dominant"].replace("t_", "")
            uf = c.get("useful_fraction")
            print(f"| {a} | {s} | {r['t_compute']*1e3:.2f} | {r['t_memory']*1e3:.2f} "
                  f"| {r['t_collective']*1e3:.2f} | {dom} | "
                  f"{uf:.2f} | {c['memory'].get('per_device_gb', float('nan')):.1f} "
                  f"| {c.get('n_micro', 1)} |" if uf is not None else
                  f"| {a} | {s} | {r['t_compute']*1e3:.2f} | {r['t_memory']*1e3:.2f} "
                  f"| {r['t_collective']*1e3:.2f} | {dom} | n/a "
                  f"| {c['memory'].get('per_device_gb', float('nan')):.1f} "
                  f"| {c.get('n_micro', 1)} |")
    print()


def skip_table():
    print("### Documented skips\n")
    seen = set()
    for p in sorted(glob.glob(os.path.join(ARTS, "*__single__dense.json"))):
        c = json.load(open(p))
        if c.get("status") == "skipped":
            key = (c["cell"].split("__")[0], c["cell"].split("__")[1])
            if key not in seen:
                seen.add(key)
                print(f"- `{key[0]} × {key[1]}`: {c['reason']}")
    print()


if __name__ == "__main__":
    dryrun_table()
    skip_table()
    roofline_table("dense")
    roofline_table("sparse")
