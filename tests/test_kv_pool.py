"""Paged KV cache (core.kv_pool + the paged ServeEngine; DESIGN.md §14).

Covers the ISSUE-9 acceptance surface:
  - allocator invariants (alloc/free/incref/decref/evict) under randomized
    operation sequences (hypothesis),
  - COW prefix sharing: full-page sharing, tail-page fork on the first
    divergent token, cached-first-token admission, isolation from the donor,
  - sliding-window ring page recycling (fixed physical page set across
    rotations),
  - pool exhaustion queues requests instead of crashing,
  - worst-case page-budget rejection at submit,
  - bitwise parity of the paged decode against the contiguous PR-5 path at
    both the function level (dense + sparse + ring) and the engine level
    (mixed prompt lengths, vector per-slot positions).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.attention_exec import SparseAttentionExec
from repro.core.kv_pool import PagePool, chain_digests, write_target
from repro.core.sparse_attention import sparse_decode_attention
from repro.launch.serve import Request, ServeEngine
from repro.launch.steps import causal_band_tables
from repro.models.attention import decode_attention, paged_decode_attention
from repro.models.registry import build


def _cfg():
    return get_config("qwen2-7b").reduced().replace(
        remat=False, dtype="float32", cache_dtype="float32")


def _tiny_pool(num_pages=8, layers=1, page=4, kv=1, hd=2):
    return PagePool(layers=layers, num_pages=num_pages, page=page,
                    kv_heads=kv, head_dim=hd, dtype="float32")


# ---------------------------------------------------------------------------
# allocator invariants
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=30)
@given(st.integers(0, 10_000), st.integers(4, 24))
def test_allocator_invariants_random_ops(seed, num_pages):
    """Random alloc/incref/decref/register sequences preserve the pool
    accounting: refcounts never negative, every page is in exactly one of
    {live, LRU, free}, and free + LRU + live == capacity."""
    rng = np.random.default_rng(seed)
    pool = _tiny_pool(num_pages=num_pages)
    live = {}    # pgid -> refcount we believe it has
    for opn in range(200):
        op = rng.integers(0, 4)
        if op == 0 and pool.available() > 0:
            n = int(rng.integers(1, pool.available() + 1))
            got = pool.alloc(n)
            assert len(got) == n and len(set(got)) == n
            for p in got:
                assert p != 0, "scratch page must never be allocated"
                assert live.get(p) is None, "double-allocated live page"
                live[p] = 1
        elif op == 1 and live:
            p = int(rng.choice(list(live)))
            pool.incref(p)
            live[p] += 1
        elif op == 2 and live:
            p = int(rng.choice(list(live)))
            pool.decref(p)
            live[p] -= 1
            if live[p] == 0:
                del live[p]
        elif op == 3 and live:
            # register a live page so its rc==0 fate is the LRU, not free
            p = int(rng.choice(list(live)))
            d = bytes(rng.integers(0, 256, 16, dtype=np.uint8))
            pool.register_full(p, d, b"parent", (1, 2, 3, 4))
        # invariants
        assert np.all(pool.rc >= 0)
        for p, rc in live.items():
            assert pool.rc[p] == rc, (p, rc, pool.rc[p])
        assert pool.live_pages() == len(live)
        assert len(pool.free) + len(pool.lru) + len(live) == pool.capacity
        assert not (set(pool.free) & set(pool.lru)), "page in free AND lru"
        assert not (set(pool.free) | set(pool.lru)) & set(live)
    # drain: every live page decrefs back to reusable
    for p, rc in list(live.items()):
        for _ in range(rc):
            pool.decref(p)
    assert pool.available() == pool.capacity
    assert pool.live_pages() == 0


def test_alloc_exhaustion_raises_and_evicts_lru():
    pool = _tiny_pool(num_pages=4)   # capacity 3
    got = pool.alloc(3)
    with pytest.raises(RuntimeError):
        pool.alloc(1)
    # registered + decref'd pages are evictable, not lost
    pool.register_full(got[0], b"d0", b"p", (1,))
    pool.decref(got[0])
    assert pool.available() == 1
    (again,) = pool.alloc(1)
    assert again == got[0]
    assert pool.stats["evictions"] == 1
    assert b"d0" not in pool.by_hash, "evicted page must leave the registry"


def test_decref_to_zero_unregistered_goes_free_registered_goes_lru():
    pool = _tiny_pool()
    a, b = pool.alloc(2)
    pool.register_full(b, b"db", b"p", (9,))
    pool.decref(a)
    pool.decref(b)
    assert a in pool.free and a not in pool.lru
    assert b in pool.lru and b not in pool.free
    # revival from the LRU keeps the registration
    pool.incref(b)
    assert b not in pool.lru and pool.rc[b] == 1
    assert pool.by_hash[b"db"] == b


def test_chain_digests_prefix_property():
    """Equal prompts -> equal chains; a divergent token changes every digest
    from its page onward and the full digest."""
    p1 = np.arange(10, dtype=np.int32)
    p2 = p1.copy()
    d1, f1 = chain_digests(p1, 4)
    d2, f2 = chain_digests(p2, 4)
    assert d1 == d2 and f1 == f2
    p2[5] ^= 1                       # inside page 1
    d3, f3 = chain_digests(p2, 4)
    assert d3[0] == d1[0] and d3[1] != d1[1] and f3 != f1


# ---------------------------------------------------------------------------
# function-level bitwise parity: paged vs contiguous decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ring", [False, True])
def test_paged_dense_decode_bitwise_vs_contiguous(ring):
    cfg = _cfg()
    if ring:
        cfg = cfg.replace(sliding_window=32)
    hd, KV, H = cfg.resolved_head_dim, cfg.num_kv_heads, cfg.num_heads
    B, page, NB = 3, 8, 4
    S = NB * page
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    posb = jnp.asarray([5, 17, 30], jnp.int32)   # vector per-slot positions

    if ring:
        from repro.models.attention import ring_kpos
        ref = decode_attention(cfg, q, kc, vc, posb, kpos=ring_kpos(posb, S))
    else:
        ref = decode_attention(cfg, q, kc, vc, posb)

    # identity page table: block nb of row b -> page 1 + b*NB + nb
    pt = (1 + np.arange(B * NB, dtype=np.int32)).reshape(B, NB)
    kp = jnp.zeros((1, 1 + B * NB, page, KV, hd), jnp.float32)
    vp = jnp.zeros_like(kp)
    kp = kp.at[0, pt].set(kc.reshape(B, NB, page, KV, hd))
    vp = vp.at[0, pt].set(vc.reshape(B, NB, page, KV, hd))
    out = paged_decode_attention(cfg, q, kp, vp, jnp.int32(0), posb,
                                 jnp.asarray(pt), page=page)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("ring", [False, True])
def test_paged_sparse_decode_bitwise_vs_contiguous(ring):
    """Contiguous and paged sparse decode share _decode_gathered; with an
    identity page table they gather the same blocks -> bitwise equal."""
    cfg = _cfg()
    if ring:
        cfg = cfg.replace(sliding_window=32)
    hd, KV, H = cfg.resolved_head_dim, cfg.num_kv_heads, cfg.num_heads
    B, block, NB = 3, 8, 4
    S = NB * block
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    posb = jnp.asarray([5, 17, 30], jnp.int32)
    t = causal_band_tables(1, NB, width=2)
    col = jnp.asarray(t["col_idx"][0])
    nval = jnp.asarray(t["nvalid"][0])

    ref = sparse_decode_attention(cfg, q, kc, vc, posb, col, nval,
                                  block=block, ring=ring)
    from repro.core.sparse_attention import paged_sparse_decode_attention
    pt = (1 + np.arange(B * NB, dtype=np.int32)).reshape(B, NB)
    kp = jnp.zeros((1, 1 + B * NB, block, KV, hd), jnp.float32)
    vp = jnp.zeros_like(kp)
    kp = kp.at[0, pt].set(kc.reshape(B, NB, block, KV, hd))
    vp = vp.at[0, pt].set(vc.reshape(B, NB, block, KV, hd))
    out = paged_sparse_decode_attention(
        cfg, q, kp, vp, jnp.int32(0), posb, jnp.asarray(pt), col, nval,
        page=block, ring=ring)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_write_target_ring_and_append():
    pt = jnp.asarray(np.array([[3, 7, -1, -1]], np.int32))
    # append: pos 5 page 4 -> block 1 -> page 7, offset 1
    phys, off = write_target(pt, jnp.asarray([5]), 4, ring=False)
    assert (int(phys[0]), int(off[0])) == (7, 1)
    # unmapped block clamps to scratch
    phys, off = write_target(pt, jnp.asarray([9]), 4, ring=False)
    assert int(phys[0]) == 0
    # ring: pos 9 in a 4x4=16 ring -> table slot (9//4) % 4 = 2 ... unmapped
    phys, off = write_target(pt, jnp.asarray([9]), 4, ring=True)
    assert int(phys[0]) == 0 and int(off[0]) == 1
    # ring wraps: pos 17 -> slot (17//4) % 4 = 0 -> page 3, offset 1
    phys, off = write_target(pt, jnp.asarray([17]), 4, ring=True)
    assert (int(phys[0]), int(off[0])) == (3, 1)


# ---------------------------------------------------------------------------
# engine level: COW prefix sharing
# ---------------------------------------------------------------------------

def _params(cfg, seed=0):
    return build(cfg).init(jax.random.key(seed))


def test_cow_fork_on_divergent_token_and_cached_first():
    """A second request with the SAME prompt admits with zero prefill
    compute (cached first token + forked tail page) and still generates the
    donor's exact continuation; a request diverging in the tail page forks
    and matches its isolated reference."""
    cfg = _cfg()
    params = _params(cfg)
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab_size, size=40).astype(np.int32)
    # page 32: one full (shared) page + an 8-token tail

    eng = ServeEngine(cfg, params, slots=2, max_len=128, paged=True)
    a = Request(rid=0, prompt=prompt, max_new=5)
    eng.run([a])
    assert eng.prefix_stats["prefill_fused"] == 1

    b = Request(rid=1, prompt=prompt.copy(), max_new=5)
    eng.run([b])
    st = eng.prefix_stats
    assert b.out == a.out
    assert st["prefill_reused"] == 1, "full hit must skip prefill entirely"
    assert st["forks"] >= 1, "tail page must be COW-forked, not shared"
    assert st["prefill_fused"] == 1, "no second fused prefill"
    assert st["prefix_hit_rate"] > 0

    # divergent LAST token: full page still shared, tail recomputed privately
    p2 = prompt.copy()
    p2[-1] = (p2[-1] + 1) % cfg.vocab_size
    c = Request(rid=2, prompt=p2, max_new=5)
    eng.run([c])
    solo = ServeEngine(cfg, params, slots=1, max_len=128, paged=False)
    ci = Request(rid=0, prompt=p2.copy(), max_new=5)
    solo.run([ci])
    assert c.out == ci.out, "fork isolation: divergent request == isolated"


def test_shared_system_prompt_prefilled_once():
    """Three requests sharing a 64-token system prompt: the prefix is
    prefilled once (one fused call; followers admit via shared pages +
    stepwise suffix), hit rate > 0, outputs equal isolated references."""
    cfg = _cfg()
    params = _params(cfg)
    rng = np.random.default_rng(1)
    sys_p = rng.integers(1, cfg.vocab_size, size=64).astype(np.int32)
    reqs = [Request(rid=i,
                    prompt=np.concatenate(
                        [sys_p,
                         rng.integers(1, cfg.vocab_size, size=5).astype(np.int32)]),
                    max_new=4)
            for i in range(3)]

    eng = ServeEngine(cfg, params, slots=2, max_len=128, paged=True)
    eng.run(reqs)
    st = eng.prefix_stats
    assert st["prefix_hit_rate"] > 0
    assert st["prefill_fused"] == 1, \
        "the shared prefix must be computed exactly once"
    assert st["prefix_tokens_reused"] >= 2 * 2 * 32   # 2 followers x 2 pages

    for r in reqs:
        solo = ServeEngine(cfg, params, slots=1, max_len=128, paged=False)
        ri = Request(rid=0, prompt=r.prompt.copy(), max_new=4)
        solo.run([ri])
        assert r.out == ri.out, r.rid


# ---------------------------------------------------------------------------
# engine level: ring recycling, exhaustion, budget
# ---------------------------------------------------------------------------

def test_ring_page_recycling_fixed_page_set():
    """Sliding-window decode recycles the slot's OWN pages across rotations
    (the page-table row never changes; rotated-out pages are overwritten in
    place) and matches the contiguous ring engine."""
    cfg = get_config("mixtral-8x7b").reduced().replace(
        remat=False, dtype="float32", cache_dtype="float32")
    params = _params(cfg, seed=1)
    rng = np.random.default_rng(2)
    prompt = rng.integers(1, cfg.vocab_size, size=70).astype(np.int32)
    # window 64 -> ring; prompt wraps it already, decode rotates further

    eng = ServeEngine(cfg, params, slots=1, max_len=64, paged=True,
                      page_size=16)
    r = Request(rid=0, prompt=prompt, max_new=8)
    eng.submit(r)
    eng.step()                       # admit (ring prefill) + first decode
    pages0 = set(eng.page_tables[0][eng.page_tables[0] >= 0].tolist())
    assert len(pages0) == 4, "a wrapped ring maps exactly nblocks pages"
    while not r.done:
        eng.step()
    pages1 = set(eng.page_tables[0][eng.page_tables[0] >= 0].tolist())
    assert pages1 == pages0, "rotation must recycle, not allocate"
    assert eng.pool.stats["allocs"] == 4

    ec = ServeEngine(cfg, params, slots=1, max_len=64, paged=False)
    rc = Request(rid=0, prompt=prompt.copy(), max_new=8)
    ec.run([rc])
    assert r.out == rc.out


def test_pool_exhaustion_queues_until_pages_free():
    """More concurrent demand than pages: later requests WAIT (admission
    defers) and complete when earlier ones release; nothing crashes."""
    cfg = _cfg()
    params = _params(cfg)
    rng = np.random.default_rng(3)
    # capacity 2 pages; each request needs 1 (prompt 20 + 4 < page 32)...
    # so force 2 pages each via prompt 40
    eng = ServeEngine(cfg, params, slots=4, max_len=128, paged=True,
                      num_pages=3)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=40).astype(np.int32),
                    max_new=4)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    saw_wait = False
    for _ in range(200):
        if not (eng.waiting or any(x is not None for x in eng.active)):
            break
        eng.step()
        saw_wait = saw_wait or bool(eng.waiting)
    assert all(r.done for r in reqs)
    assert saw_wait, "the pool was sized to force queueing"


def test_submit_rejects_impossible_page_budget():
    cfg = _cfg()
    params = _params(cfg)
    eng = ServeEngine(cfg, params, slots=2, max_len=128, paged=True,
                      num_pages=3)   # capacity 2 pages = 64 positions
    bad = Request(rid=0, prompt=np.arange(1, 70, dtype=np.int32), max_new=30)
    with pytest.raises(ValueError, match="page budget"):
        eng.submit(bad)
    # a feasible request still passes the same gate
    eng.submit(Request(rid=1, prompt=np.arange(1, 30, dtype=np.int32),
                       max_new=4))


def test_paged_capability_gate():
    cfg = get_config("rwkv6-7b").reduced().replace(remat=False)
    b = build(cfg)
    assert not b.supports_paged_cache and not b.supports_sparse_decode
    params = b.init(jax.random.key(0))
    with pytest.raises(NotImplementedError, match="supports_paged_cache|recurrent"):
        ServeEngine(cfg, params, slots=1, max_len=32, paged=True)
    with pytest.raises(NotImplementedError, match="recurrent"):
        ServeEngine(cfg, params, slots=1, max_len=32,
                    spion={"block": 8})


# ---------------------------------------------------------------------------
# engine level: bitwise regression vs the contiguous PR-5 path
# ---------------------------------------------------------------------------

def test_engine_paged_equals_contiguous_mixed_lengths():
    """Covering pattern, mixed prompt lengths, more requests than slots
    (vector per-slot positions + slot reuse): the paged engine's outputs
    equal the contiguous engine's token-for-token, dense and sparse."""
    cfg = _cfg()
    params = _params(cfg)
    rng = np.random.default_rng(4)
    lens = (7, 19, 33, 50, 12)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in lens]
    nrb = 128 // 16
    tabs = dict({k: jnp.asarray(v)
                 for k, v in causal_band_tables(cfg.num_layers, nrb).items()},
                block=16)
    for spion in (None, tabs):
        outs = {}
        for paged in (True, False):
            eng = ServeEngine(cfg, params, slots=2, max_len=128,
                              spion=spion, paged=paged, prefill_bucket=16)
            reqs = [Request(rid=i, prompt=p.copy(), max_new=6)
                    for i, p in enumerate(prompts)]
            eng.run(reqs)
            outs[paged] = [r.out for r in reqs]
        assert outs[True] == outs[False], ("sparse" if spion else "dense")


def test_engine_paged_hybrid_matches_contiguous():
    cfg = get_config("zamba2-1.2b").reduced().replace(
        remat=False, dtype="float32", cache_dtype="float32")
    params = _params(cfg, seed=2)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (9, 17)]
    outs = {}
    for paged in (True, False):
        eng = ServeEngine(cfg, params, slots=2, max_len=64, paged=paged)
        reqs = [Request(rid=i, prompt=p.copy(), max_new=4)
                for i, p in enumerate(prompts)]
        eng.run(reqs)
        outs[paged] = [r.out for r in reqs]
    assert outs[True] == outs[False]
