"""Pattern generation (paper Alg. 3/4): oracle equality + invariants."""
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pattern import (avg_pool, bigbird_pattern, density, diag_conv,
                                diagonal_filter, flood_fill_iterative,
                                flood_fill_recursive, generate_pattern,
                                upsample, window_pattern)
from repro.core.sparse_attention import bcsr_from_blockmask


def _run_recursive(po, t):
    n = po.shape[0]
    fl = np.zeros((n, n), np.int8)
    sys.setrecursionlimit(1_000_000)
    for i in range(n):
        flood_fill_recursive(po, 0, i, fl, t)
    for j in range(n):
        flood_fill_recursive(po, j, 0, fl, t)
    return fl


@given(st.integers(0, 10_000), st.integers(4, 24), st.floats(0.5, 0.99))
def test_floodfill_iterative_matches_recursive_oracle(seed, n, q):
    rng = np.random.default_rng(seed)
    po = rng.random((n, n))
    t = float(np.quantile(po, q))
    fl_it = np.zeros((n, n), np.int8)
    flood_fill_iterative(po, fl_it, t)
    assert np.array_equal(fl_it, _run_recursive(po, t))


@given(st.integers(0, 10_000), st.integers(4, 20))
def test_floodfill_marks_only_above_threshold(seed, n):
    rng = np.random.default_rng(seed)
    po = rng.random((n, n))
    t = float(np.quantile(po, 0.8))
    fl = np.zeros((n, n), np.int8)
    flood_fill_iterative(po, fl, t)
    assert np.all(po[fl.astype(bool)] > t)


@given(st.integers(0, 2_000), st.sampled_from(["c", "f", "cf"]),
       st.booleans())
def test_generate_pattern_invariants(seed, variant, causal):
    rng = np.random.default_rng(seed)
    L, B = 128, 16
    a_s = rng.random((L, L))
    pat = generate_pattern(a_s, variant=variant, conv_filter_size=7,
                           block_size=B, alpha_quantile=0.9, causal=causal)
    n = L // B
    assert pat.shape == (n, n)
    assert set(np.unique(pat)).issubset({0, 1})
    assert np.all(np.diag(pat) == 1), "Alg.3 lines 9-10: diagonal forced"
    if causal:
        assert np.all(np.triu(pat, 1) == 0)


def test_diag_conv_matches_eq3():
    """conv_out(i,j) = sum_f A(i+f,j+f) * w_f, zero padded."""
    rng = np.random.default_rng(0)
    a = rng.random((16, 16))
    w = diagonal_filter(5)
    out = diag_conv(a, w)
    i, j = 3, 7
    expect = sum(w[f] * a[i + f, j + f] for f in range(5))
    assert np.isclose(out[i, j], expect)
    # zero padding at the edge
    i = 14
    expect = sum(w[f] * a[i + f, j + f] for f in range(2))
    assert np.isclose(out[i, j], expect)


def test_avgpool_and_upsample_roundtrip_shape():
    rng = np.random.default_rng(0)
    a = rng.random((64, 64))
    p = avg_pool(a, 16)
    assert p.shape == (4, 4)
    u = upsample((p > p.mean()).astype(np.int8), 16)
    assert u.shape == (64, 64)
    assert np.array_equal(u[:16, :16], np.full((16, 16), u[0, 0]))


def test_fixed_patterns():
    m = bigbird_pattern(16, window=3, num_global=2, num_random=2)
    assert np.all(np.diag(m) == 1)
    assert np.all(m[:2, :] == 1) and np.all(m[:, :2] == 1)
    w = window_pattern(16, window=3)
    assert w[8, 8] and w[8, 7] and w[8, 9] and not w[8, 11]
    assert 0 < density(w) < 0.3


def test_bcsr_from_blockmask_padding():
    mask = np.zeros((4, 4), bool)
    mask[0, :3] = True
    mask[2, 1] = True
    b = bcsr_from_blockmask(mask, 8)
    assert b.col_idx.shape == (4, 3)
    assert int(b.nvalid[0]) == 3 and int(b.nvalid[2]) == 1
    assert int(b.col_idx[2, 0]) == 1 and int(b.col_idx[2, 1]) == -1
