"""Multi-host fault tolerance, tested with REAL `jax.distributed` CPU
processes (gloo collectives) — not fake devices: every test here spawns N
interpreters that rendezvous through a coordinator, so process boundaries,
kill -9, SIGTERM delivery and cross-process file visibility are all real.

Gated behind SPION_MP_TESTS=1 (the tier1-multiprocess CI job sets it): each
case pays a full jit compile per process, which would double the plain
tier-1 wall clock for coverage that has its own dedicated job.

The end-to-end case is the PR's acceptance criterion: a 2-process run
through the dense->sparse transition is SIGKILLed mid-sparse-phase, resumed
on 2 processes (restored-plan digest check runs in-band), then resumed
again on ONE process (elastic: changed host count re-shards the
mesh-agnostic checkpoint), and the stitched per-step losses must match an
uninterrupted reference run to numerical tolerance.
"""
import os
import re
import signal
import subprocess
import sys

import pytest

from conftest import free_port, run_distributed_case

pytestmark = pytest.mark.skipif(
    os.environ.get("SPION_MP_TESTS") != "1",
    reason="multi-process suite (set SPION_MP_TESTS=1; CI: tier1-multiprocess)")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -- runtime primitives --------------------------------------------------------

RUNTIME_CODE = """
import os
import numpy as np
pid = int(os.environ["MP_PID"]); nproc = int(os.environ["MP_NPROC"])
from repro.distributed import runtime
runtime.initialize(f"localhost:{os.environ['MP_PORT']}", nproc, pid)
import jax
assert jax.process_count() == nproc
assert runtime.is_coordinator() == (pid == 0)
g = runtime.host_allgather(np.asarray([pid * 10 + 7], np.int32))
assert g.tolist() == [[7], [17]], g
# broadcast: only process 0 knows the payload (shapes, dtypes, meta)
if runtime.is_coordinator():
    arrays = {"a": np.arange(12, dtype=np.int32).reshape(3, 4),
              "b": np.full(5, 3.5, np.float64),
              "c": np.arange(7, dtype=np.uint8)}
    meta = {"block": 16, "note": "hi"}
else:
    arrays, meta = None, None
out, m = runtime.broadcast_arrays(arrays, meta)
assert m == {"block": 16, "note": "hi"}
assert out["a"].dtype == np.int32 and \
    out["a"].tolist() == np.arange(12).reshape(3, 4).tolist()
assert out["b"].dtype == np.float64 and np.allclose(out["b"], 3.5)
assert out["c"].dtype == np.uint8 and out["c"].tolist() == list(range(7))
runtime.assert_in_sync("payload", runtime.payload_digest(out, m))
assert runtime.any_flag(pid == 1) is True   # OR: one process's flag reaches all
assert runtime.any_flag(False) is False
runtime.barrier("end")
print("RT_OK")
"""


def test_runtime_primitives_two_processes():
    outs = run_distributed_case(RUNTIME_CODE, nproc=2)
    assert all("RT_OK" in o for o in outs)


DIGEST_MISMATCH_CODE = """
import os
import numpy as np
pid = int(os.environ["MP_PID"])
from repro.distributed import runtime
runtime.initialize(f"localhost:{os.environ['MP_PORT']}",
                   int(os.environ["MP_NPROC"]), pid)
d = runtime.payload_digest({"t": np.asarray([pid], np.int32)})  # per-process
try:
    runtime.assert_in_sync("divergent_plan", d)
    print("NO_RAISE")
except RuntimeError as e:
    assert "divergent_plan" in str(e)
    print("CAUGHT")
"""


def test_divergent_digest_fails_loudly_everywhere():
    outs = run_distributed_case(DIGEST_MISMATCH_CODE, nproc=2)
    assert all("CAUGHT" in o for o in outs)
    assert not any("NO_RAISE" in o for o in outs)


# -- checkpoint: process-0-writes / all-read / commit barrier ------------------

CKPT_CODE = """
import os
import numpy as np
import jax
pid = int(os.environ["MP_PID"])
from repro.distributed import runtime
runtime.initialize(f"localhost:{os.environ['MP_PORT']}",
                   int(os.environ["MP_NPROC"]), pid)
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager
from repro.launch.mesh import make_distributed_mesh
mesh = make_distributed_mesh()
ckpt_dir = os.environ["MP_SCRATCH"]
mgr = CheckpointManager(ckpt_dir, keep=2)
assert mgr.multiprocess and mgr.is_writer == (pid == 0)
tree = runtime.make_global(
    mesh, {"w": np.arange(8.0).reshape(2, 4), "count": np.int32(3)},
    {"w": P("pod", None), "count": P()})
assert not tree["w"].is_fully_addressable   # really spans both processes
mgr.save(7, tree, extra={"phase": "sparse"},
         extra_arrays={"tab": np.arange(6, dtype=np.int32)})
mgr.wait()  # commit barrier: from here EVERY process sees the step
assert mgr.latest_step() == 7
sh = {"w": NamedSharding(mesh, P("pod", None)),
      "count": NamedSharding(mesh, P())}
got, step, extra = mgr.restore(target=tree, shardings=sh)
assert step == 7 and extra["phase"] == "sparse"
assert extra["_arrays"]["tab"].tolist() == list(range(6))
w = runtime.fully_replicated_host(got)["w"]
assert w.tolist() == np.arange(8.0).reshape(2, 4).tolist()
dirs = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
assert dirs == ["step_000000007"], dirs   # exactly one writer
print("CKPT_OK")
"""


def test_checkpoint_multiprocess_roundtrip(tmp_path):
    outs = run_distributed_case(CKPT_CODE, nproc=2,
                                env_extra={"MP_SCRATCH": str(tmp_path)})
    assert all("CKPT_OK" in o for o in outs)


# -- end-to-end fault injection ------------------------------------------------

def _launch_workers(nproc, port, ckpt_dir, target_step, chaos=None,
                    chaos_pid=None):
    """Spawn `nproc` instances of tests/mp_train_worker.py; `chaos` env vars
    are applied only to `chaos_pid`. Returns the Popen list."""
    procs = []
    for pid in range(nproc):
        env = {"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
               "PATH": "/usr/bin:/bin"}
        if chaos and pid == chaos_pid:
            env.update(chaos)
        procs.append(subprocess.Popen(
            [sys.executable, "tests/mp_train_worker.py",
             "--pid", str(pid), "--nproc", str(nproc), "--port", str(port),
             "--ckpt-dir", ckpt_dir, "--target-step", str(target_step)],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            env=env, cwd=ROOT))
    return procs


def _drain(procs, timeout=600):
    outs = []
    try:
        for p in procs:
            out, err = p.communicate(timeout=timeout)
            outs.append((p.returncode, out, err))
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    return outs


def _losses(stdout):
    out = {}
    for m in re.finditer(r"^LOSS,(\d+),([\d.eE+-]+)$", stdout, re.M):
        out[int(m.group(1))] = float(m.group(2))
    return out


def _committed_steps(ckpt_dir):
    return sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                  if d.startswith("step_")
                  and os.path.exists(os.path.join(ckpt_dir, d, "DONE")))


def test_fault_recovery_end_to_end(tmp_path):
    """SIGKILL a worker mid-sparse-phase; resume on 2 processes, then on 1
    (changed host count); stitched losses must match the uninterrupted
    reference."""
    # reference: uninterrupted 2-process run to step 20
    ref_dir = str(tmp_path / "ref")
    outs = _drain(_launch_workers(2, free_port(), ref_dir, 20))
    assert all(rc == 0 for rc, _, _ in outs), outs[0][2][-2000:]
    assert "phase=sparse" in outs[0][1]
    ref = _losses(outs[0][1])
    assert sorted(ref) == list(range(20))

    # chaos: kill process 1 with SIGKILL at step 13 (ckpts at 5 and 10; the
    # step-10 async write may be in flight — either fallback is legitimate)
    chaos_dir = str(tmp_path / "chaos")
    procs = _launch_workers(
        2, free_port(), chaos_dir, 20,
        chaos={"SPION_CHAOS_KILL_STEP": "13", "SPION_CHAOS_KILL_PROC": "1",
               "SPION_CHAOS_SIGNAL": "KILL"}, chaos_pid=1)
    procs[1].wait(timeout=600)
    assert procs[1].returncode == -signal.SIGKILL
    # the survivor is wedged in a collective that will never complete — the
    # scheduler kills the remaining fleet (what a real supervisor does)
    procs[0].kill()
    _drain(procs, timeout=60)
    committed = _committed_steps(chaos_dir)
    assert committed and committed[-1] in (5, 10), committed

    # resume leg A: same process count. Restores the last COMMITTED step,
    # verifies the restored plan digest across processes in-band
    # (Trainer._restore_latest -> verify_plan_sync), replays to step 15.
    outs = _drain(_launch_workers(2, free_port(), chaos_dir, 15))
    assert all(rc == 0 for rc, _, _ in outs), outs[0][2][-2000:]
    la = _losses(outs[0][1])
    assert min(la) == committed[-1]  # resumed exactly at the commit point

    # resume leg B: ONE process — elastic restore of the 2-process
    # checkpoint onto a different host count — to step 20.
    outs = _drain(_launch_workers(1, free_port(), chaos_dir, 20))
    assert all(rc == 0 for rc, _, _ in outs), outs[0][2][-2000:]
    assert "phase=sparse" in outs[0][1]
    lb = _losses(outs[0][1])
    assert min(lb) == 15 and max(lb) == 19

    # step-exact recovery: every resumed step's loss matches the
    # uninterrupted reference (reduction-order wiggle only)
    resumed = {**la, **lb}
    for s, v in resumed.items():
        assert abs(v - ref[s]) <= 1e-3 + 1e-3 * abs(ref[s]), (s, v, ref[s])

    # the torn step-10 tmp dir (if the kill caught the async write mid-
    # flight) was reaped by a later save
    assert not any(d.startswith(".tmp_step_")
                   for d in os.listdir(chaos_dir))


def test_sigterm_preemption_saves_fleetwide(tmp_path):
    """SIGTERM on ONE process: the per-step any_flag OR makes every process
    save at the same (non-multiple-of-ckpt_every) step and exit cleanly."""
    ckpt_dir = str(tmp_path / "term")
    procs = _launch_workers(
        2, free_port(), ckpt_dir, 20,
        chaos={"SPION_CHAOS_KILL_STEP": "12", "SPION_CHAOS_KILL_PROC": "1",
               "SPION_CHAOS_SIGNAL": "TERM"}, chaos_pid=1)
    outs = _drain(procs)
    assert all(rc == 0 for rc, _, _ in outs), outs[1][2][-2000:]
    for _, out, _ in outs:
        assert "WORKER_DONE step=12" in out and "preempted=1" in out
    assert _committed_steps(ckpt_dir)[-1] == 12


# -- self-healing: supervisor + divergence rollback (DESIGN.md §13) ------------

def _run_supervised(ckpt_dir, once_dir, target_step, chaos_env, *,
                    hang_timeout=120, max_respawns=3, timeout=600):
    """Run `python -m repro.launch.supervise` over 2 mp_train_worker
    processes with the given chaos arming; returns (rc, stdout+stderr)."""
    env = {"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
           "PATH": "/usr/bin:/bin", "SPION_CHAOS_ONCE_DIR": once_dir,
           **chaos_env}
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.supervise",
         "--nproc", "2", "--ckpt-dir", ckpt_dir,
         "--dead-timeout", "60", "--hang-timeout", str(hang_timeout),
         "--poll-interval", "0.5", "--max-respawns", str(max_respawns),
         "--backoff-base", "0.2", "--backoff-max", "1.0",
         "--", sys.executable, "tests/mp_train_worker.py",
         "--ckpt-dir", ckpt_dir, "--target-step", str(target_step),
         "--heartbeat-interval", "0.3"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env, cwd=ROOT, timeout=timeout)
    return r.returncode, r.stdout


def _reference_losses(tmp_path, target_step, skip_window=None):
    ref_dir = str(tmp_path / "ref")
    extra = (["--skip-window", skip_window] if skip_window else [])
    procs = []
    port = free_port()
    for pid in range(2):
        procs.append(subprocess.Popen(
            [sys.executable, "tests/mp_train_worker.py",
             "--pid", str(pid), "--nproc", "2", "--port", str(port),
             "--ckpt-dir", ref_dir, "--target-step", str(target_step)] + extra,
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            env={"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
                 "PATH": "/usr/bin:/bin"}, cwd=ROOT))
    outs = _drain(procs)
    assert all(rc == 0 for rc, _, _ in outs), outs[0][2][-2000:]
    ref = _losses(outs[0][1])
    assert sorted(ref) == list(range(target_step))
    return ref


def _assert_stitched(got, ref, steps):
    assert sorted(got) == list(range(steps)), sorted(got)
    for s in range(steps):
        assert abs(got[s] - ref[s]) <= 1e-3 + 1e-3 * abs(ref[s]), \
            (s, got[s], ref[s])


def test_supervisor_heals_kill_end_to_end(tmp_path):
    """kill -9 on worker 1 at step 12: the supervisor notices the non-zero
    exit, SIGKILLs the wedged survivor, respawns the fleet, and the respawn
    resumes from the last committed checkpoint and reaches the target with
    reference-matching losses — zero manual intervention."""
    ref = _reference_losses(tmp_path, 16)
    ckpt_dir = str(tmp_path / "kill")
    rc, out = _run_supervised(
        ckpt_dir, str(tmp_path / "once_kill"), 16,
        {"SPION_CHAOS_KILL_STEP": "12", "SPION_CHAOS_KILL_PROC": "1",
         "SPION_CHAOS_SIGNAL": "KILL"})
    assert rc == 0, out[-4000:]
    assert "SUPERVISOR fault gen=0" in out and "exit=-9" in out
    assert "SUPERVISOR respawn gen=1" in out
    assert "SUPERVISOR done" in out
    assert "WORKER_DONE step=16" in out
    _assert_stitched(_losses(out), ref, 16)


def test_supervisor_heals_hang_end_to_end(tmp_path):
    """Chaos hang at step 12 (both workers sleep inside the loop): the beat
    threads keep ts fresh, so only the step-progress watchdog can see it.
    The supervisor declares the fleet hung, kills and respawns it; the
    once-marker stops the replay from re-hanging."""
    ref = _reference_losses(tmp_path, 16)
    ckpt_dir = str(tmp_path / "hang")
    rc, out = _run_supervised(
        ckpt_dir, str(tmp_path / "once_hang"), 16,
        {"SPION_CHAOS_HANG_STEP": "12"},
        hang_timeout=120, timeout=600)
    assert rc == 0, out[-4000:]
    assert "hung" in out and "SUPERVISOR fault gen=0" in out
    assert "SUPERVISOR done" in out
    assert "WORKER_DONE step=16" in out
    _assert_stitched(_losses(out), ref, 16)


def test_divergence_rollback_fleetwide(tmp_path):
    """NaN-poisoned params on ONE process at step 14: the global-mean loss
    carries the poison to every process, the OR'd sentinel flag rolls the
    whole fleet back to the pinned good step 10 at the same step, the
    poisoned step-15 save is quarantined, the data window [10, 14] is
    skipped, and the run completes unattended with losses matching a
    reference that pre-skips the window."""
    ref = _reference_losses(tmp_path, 18, skip_window="10:14")
    ckpt_dir = str(tmp_path / "nan")
    procs = _launch_workers(
        2, free_port(), ckpt_dir, 18,
        chaos={"SPION_CHAOS_NAN_STEP": "14", "SPION_CHAOS_NAN_PROC": "1",
               "SPION_CHAOS_ONCE_DIR": str(tmp_path / "once_nan")},
        chaos_pid=1)
    outs = _drain(procs)
    assert all(rc == 0 for rc, _, _ in outs), outs[1][2][-3000:]
    for _, out, _ in outs:
        assert "WORKER_DONE step=18" in out and "rollbacks=1" in out
    assert "SPION_EVENT" in outs[0][1]
    assert '"event": "rollback"' in outs[0][1]
    # the poisoned post-divergence save was moved aside, then the healthy
    # replay re-committed step 15 under the canonical name
    assert os.path.isdir(os.path.join(ckpt_dir, "quarantined_step_000000015"))
    assert 15 in _committed_steps(ckpt_dir)
    _assert_stitched(_losses(outs[0][1]), ref, 18)
