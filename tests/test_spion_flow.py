"""SPION 3-phase controller + end-to-end training integration."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SpionConfig, get_config
from repro.core.spion import SpionController, SpionState
from repro.core.variants import fixed_pattern_tables, lsh_attention
from repro.launch.train import Trainer
from repro.models.registry import build


def _controller(**kw):
    base = dict(enabled=True, variant="cf", conv_filter_size=7, block_size=16,
                alpha_quantile=0.9, transition_tol=0.05, min_dense_epochs=1,
                max_dense_epochs=10)
    base.update(kw)
    return SpionController(SpionConfig(**base), causal=False, seq_len=64)


def _pooled(rng, Ly=2, n=4):
    return rng.random((Ly, n, n))


def test_transition_on_stable_frobenius(rng):
    ctl = _controller()
    st = SpionState()
    pooled = _pooled(rng)
    frob = np.array([5.0, 5.0])
    # identical frobenius every epoch -> distances 0,0 -> |d1-d0| < tol
    for _ in range(3):
        st = ctl.observe_epoch(st, pooled, frob)
    assert st.phase == "sparse"
    assert st.tables is not None
    assert st.tables["col_idx"].shape[0] == 2  # per-layer patterns


def test_no_transition_while_unstable(rng):
    ctl = _controller(transition_tol=1e-6, max_dense_epochs=100)
    st = SpionState()
    pooled = _pooled(rng)
    for e in range(5):
        frob = np.array([float(2 ** e), float(2 ** e)])  # diverging distances
        st = ctl.observe_epoch(st, pooled, frob)
    assert st.phase == "dense"


def test_forced_transition_at_max_epochs(rng):
    ctl = _controller(transition_tol=0.0, max_dense_epochs=3)
    st = SpionState()
    pooled = _pooled(rng)
    for e in range(3):
        st = ctl.observe_epoch(st, pooled, np.array([float(e * 100), 0.0]))
    assert st.phase == "sparse"


def test_state_serialization_roundtrip(rng):
    ctl = _controller()
    st = SpionState()
    pooled = _pooled(rng)
    for _ in range(3):
        st = ctl.observe_epoch(st, pooled, np.array([1.0, 1.0]))
    d = st.to_py()
    st2 = SpionState.from_py(d)
    assert st2.phase == st.phase
    np.testing.assert_array_equal(np.asarray(st2.tables["col_idx"]),
                                  np.asarray(st.tables["col_idx"]))


def test_trainer_three_phase_and_loss_decreases(tmp_path):
    cfg = get_config("spion-lra").replace(
        num_layers=2, d_ff=128, vocab_size=64,
        spion=SpionConfig(enabled=True, variant="cf", conv_filter_size=5,
                          block_size=16, alpha_quantile=0.85,
                          transition_tol=1e9, min_dense_epochs=1,
                          max_dense_epochs=3))
    tr = Trainer(cfg, seq_len=64, batch=8, lr=1e-3, steps_per_epoch=5,
                 ckpt_dir=str(tmp_path))
    losses = tr.train(40, ckpt_every=20, log_every=100, log=lambda *a: None)
    assert tr.spion_state.phase == "sparse", "transition must have happened"
    assert 0 < tr.spion_state.density < 1
    assert np.mean(losses[-8:]) < np.mean(losses[:8]), "loss should decrease"


def test_trainer_checkpoint_resume(tmp_path):
    cfg = get_config("spion-lra").replace(num_layers=2, d_ff=64, vocab_size=64,
                                          spion=SpionConfig(enabled=False))
    tr = Trainer(cfg, seq_len=32, batch=4, ckpt_dir=str(tmp_path), seed=3)
    tr.train(10, ckpt_every=10, log_every=100, log=lambda *a: None)
    w_before = np.asarray(jax.device_get(
        jax.tree_util.tree_leaves(tr.params)[0]))
    tr2 = Trainer(cfg, seq_len=32, batch=4, ckpt_dir=str(tmp_path), seed=99)
    assert tr2.maybe_resume()
    assert tr2.step == 10
    w_after = np.asarray(jax.device_get(
        jax.tree_util.tree_leaves(tr2.params)[0]))
    np.testing.assert_allclose(w_before, w_after)


def test_sparse_phase_matches_dense_when_full_pattern():
    """With alpha=0 the generated pattern keeps every block -> sparse forward
    must equal dense forward (up to the zero-correction, which vanishes)."""
    cfg = get_config("spion-lra").replace(num_layers=2, d_ff=64, vocab_size=64)
    b = build(cfg)
    params = b.init(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 64), 0, 64)}
    dense, _ = b.forward(params, batch)
    tabs = fixed_pattern_tables("window", 64, 16, cfg.num_layers, window=9999)
    sparse, _ = b.forward(params, batch, spion=tabs)
    np.testing.assert_allclose(np.asarray(dense, np.float32),
                               np.asarray(sparse, np.float32), atol=2e-2)


def test_lsh_attention_baseline_shape_and_locality():
    q = jax.random.normal(jax.random.key(0), (2, 128, 4, 16))
    out = lsh_attention(q, q, q, num_hashes=2, bucket_size=32)
    assert out.shape == q.shape
    assert not bool(jnp.isnan(out).any())
