"""SPION 3-phase controller + end-to-end training integration."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SpionConfig, get_config
from repro.core.spion import SpionController, SpionState
from repro.core.variants import fixed_pattern_tables, lsh_attention
from repro.launch.train import Trainer
from repro.models.registry import build


def _controller(**kw):
    base = dict(enabled=True, variant="cf", conv_filter_size=7, block_size=16,
                alpha_quantile=0.9, transition_tol=0.05, min_dense_epochs=1,
                max_dense_epochs=10)
    base.update(kw)
    return SpionController(SpionConfig(**base), causal=False, seq_len=64)


def _pooled(rng, Ly=2, n=4):
    return rng.random((Ly, n, n))


def test_transition_on_stable_frobenius(rng):
    ctl = _controller()
    st = SpionState()
    pooled = _pooled(rng)
    frob = np.array([5.0, 5.0])
    # identical frobenius every epoch -> distances 0,0 -> |d1-d0| < tol
    for _ in range(3):
        st = ctl.observe_epoch(st, pooled, frob)
    assert st.phase == "sparse"
    assert st.tables is not None
    assert st.tables["col_idx"].shape[0] == 2  # per-layer patterns
    # generation builds the full SparsityPlan: transposed tables at KT* + stats
    Ly, nrb, _ = st.tables["col_idx"].shape
    kt = st.plan_stats["kt_star"]
    assert st.tables["row_idx"].shape == (Ly, nrb, kt)
    assert st.tables["nvalid_t"].shape == (Ly, nrb)
    assert 1 <= kt <= nrb
    assert st.plan_stats["dkv_grid_shrink"] >= 1.0


def test_no_transition_while_unstable(rng):
    ctl = _controller(transition_tol=1e-6, max_dense_epochs=100)
    st = SpionState()
    pooled = _pooled(rng)
    for e in range(5):
        frob = np.array([float(2 ** e), float(2 ** e)])  # diverging distances
        st = ctl.observe_epoch(st, pooled, frob)
    assert st.phase == "dense"


def test_forced_transition_at_max_epochs(rng):
    ctl = _controller(transition_tol=0.0, max_dense_epochs=3)
    st = SpionState()
    pooled = _pooled(rng)
    for e in range(3):
        st = ctl.observe_epoch(st, pooled, np.array([float(e * 100), 0.0]))
    assert st.phase == "sparse"


def test_state_serialization_roundtrip(rng):
    ctl = _controller()
    st = SpionState()
    pooled = _pooled(rng)
    for _ in range(3):
        st = ctl.observe_epoch(st, pooled, np.array([1.0, 1.0]))
    d = st.to_py()
    st2 = SpionState.from_py(d)
    assert st2.phase == st.phase
    for k in ("col_idx", "nvalid", "row_idx", "nvalid_t"):
        np.testing.assert_array_equal(np.asarray(st2.tables[k]),
                                      np.asarray(st.tables[k]))
    assert st2.plan_stats == st.plan_stats


def test_state_serialization_binary_arrays_path(rng):
    """to_py(include_tables=False) + table_arrays() round-trips the plan via
    the checkpoint extra_arrays channel (no JSON-encoded tables)."""
    ctl = _controller()
    st = SpionState()
    for _ in range(3):
        st = ctl.observe_epoch(st, _pooled(rng), np.array([1.0, 1.0]))
    d = st.to_py(include_tables=False)
    assert "tables" not in d and d["tables_meta"]["block"] == 16
    st2 = SpionState.from_py(d, st.table_arrays())
    for k in ("col_idx", "nvalid", "row_idx", "nvalid_t"):
        np.testing.assert_array_equal(np.asarray(st2.tables[k]),
                                      np.asarray(st.tables[k]))
    assert st2.tables["block"] == st.tables["block"]


def test_state_meta_without_arrays_fails_loudly(rng):
    """tables_meta promises binary plan arrays; restoring without them must
    raise, not silently resume the sparse phase with tables=None."""
    ctl = _controller()
    st = SpionState()
    for _ in range(3):
        st = ctl.observe_epoch(st, _pooled(rng), np.array([1.0, 1.0]))
    d = st.to_py(include_tables=False)
    with pytest.raises(ValueError, match="plan arrays"):
        SpionState.from_py(d)


def test_legacy_state_without_plan_rebuilds_transposed_tables(rng):
    """A pre-SparsityPlan checkpoint (forward tables only) must not silently
    drop the transposed tables on resume — from_py rebuilds them host-side."""
    ctl = _controller()
    st = SpionState()
    for _ in range(3):
        st = ctl.observe_epoch(st, _pooled(rng), np.array([1.0, 1.0]))
    d = st.to_py()
    legacy_tables = {k: d["tables"][k] for k in ("col_idx", "nvalid", "block")}
    st2 = SpionState.from_py({**d, "tables": legacy_tables, "plan_stats": None})
    np.testing.assert_array_equal(np.asarray(st2.tables["row_idx"]),
                                  np.asarray(st.tables["row_idx"]))
    np.testing.assert_array_equal(np.asarray(st2.tables["nvalid_t"]),
                                  np.asarray(st.tables["nvalid_t"]))
    assert st2.plan_stats["kt_star"] == st.plan_stats["kt_star"]


def test_trainer_three_phase_and_loss_decreases(tmp_path):
    cfg = get_config("spion-lra").replace(
        num_layers=2, d_ff=128, vocab_size=64,
        spion=SpionConfig(enabled=True, variant="cf", conv_filter_size=5,
                          block_size=16, alpha_quantile=0.85,
                          transition_tol=1e9, min_dense_epochs=1,
                          max_dense_epochs=3))
    tr = Trainer(cfg, seq_len=64, batch=8, lr=1e-3, steps_per_epoch=5,
                 ckpt_dir=str(tmp_path))
    losses = tr.train(40, ckpt_every=20, log_every=100, log=lambda *a: None)
    assert tr.spion_state.phase == "sparse", "transition must have happened"
    assert 0 < tr.spion_state.density < 1
    assert np.mean(losses[-8:]) < np.mean(losses[:8]), "loss should decrease"


def test_trainer_checkpoint_resume(tmp_path):
    cfg = get_config("spion-lra").replace(num_layers=2, d_ff=64, vocab_size=64,
                                          spion=SpionConfig(enabled=False))
    tr = Trainer(cfg, seq_len=32, batch=4, ckpt_dir=str(tmp_path), seed=3)
    tr.train(10, ckpt_every=10, log_every=100, log=lambda *a: None)
    w_before = np.asarray(jax.device_get(
        jax.tree_util.tree_leaves(tr.params)[0]))
    tr2 = Trainer(cfg, seq_len=32, batch=4, ckpt_dir=str(tmp_path), seed=99)
    assert tr2.maybe_resume()
    assert tr2.step == 10
    w_after = np.asarray(jax.device_get(
        jax.tree_util.tree_leaves(tr2.params)[0]))
    np.testing.assert_allclose(w_before, w_after)


def test_sparse_phase_matches_dense_when_full_pattern():
    """With alpha=0 the generated pattern keeps every block -> sparse forward
    must equal dense forward (up to the zero-correction, which vanishes)."""
    cfg = get_config("spion-lra").replace(num_layers=2, d_ff=64, vocab_size=64)
    b = build(cfg)
    params = b.init(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 64), 0, 64)}
    dense, _ = b.forward(params, batch)
    tabs = fixed_pattern_tables("window", 64, 16, cfg.num_layers, window=9999)
    sparse, _ = b.forward(params, batch, spion=tabs)
    np.testing.assert_allclose(np.asarray(dense, np.float32),
                               np.asarray(sparse, np.float32), atol=2e-2)


def test_trainer_sparse_phase_resume_preserves_plan(tmp_path):
    """Resume in the sparse phase restores the FULL SparsityPlan (incl. the
    transposed tables, persisted binary via checkpoint extra_arrays)."""
    cfg = get_config("spion-lra").replace(
        num_layers=2, d_ff=64, vocab_size=64,
        spion=SpionConfig(enabled=True, variant="cf", conv_filter_size=5,
                          block_size=16, alpha_quantile=0.85,
                          transition_tol=1e9, min_dense_epochs=1,
                          max_dense_epochs=2))
    tr = Trainer(cfg, seq_len=64, batch=4, steps_per_epoch=5,
                 ckpt_dir=str(tmp_path))
    tr.train(20, ckpt_every=20, log_every=100, log=lambda *a: None)
    assert tr.spion_state.phase == "sparse"
    tr2 = Trainer(cfg, seq_len=64, batch=4, steps_per_epoch=5,
                  ckpt_dir=str(tmp_path), seed=7)
    assert tr2.maybe_resume()
    assert tr2.spion_state.phase == "sparse"
    for k in ("col_idx", "nvalid", "row_idx", "nvalid_t"):
        np.testing.assert_array_equal(np.asarray(tr2.spion_state.tables[k]),
                                      np.asarray(tr.spion_state.tables[k]))
    assert tr2.spion_state.plan_stats == tr.spion_state.plan_stats


def test_dryrun_tables_emit_plan_shapes():
    from repro.launch.steps import spion_dryrun_tables, spion_table_pspecs
    cfg = get_config("spion-lra").replace(num_layers=3)
    t = spion_dryrun_tables(cfg, 256)
    Ly, nrb, _ = t["col_idx"].shape
    assert Ly == 3 and nrb == 256 // t["block"]
    assert t["row_idx"].shape == (Ly, nrb, t["kt_star"])
    assert t["nvalid_t"].shape == (Ly, nrb)
    assert int(t["nvalid_t"].max()) == t["kt_star"] <= nrb
    specs = spion_table_pspecs(t)
    assert set(specs) == set(t)
    assert specs["block"] is None and specs["kt_star"] is None
    assert all(specs[k] is not None for k in
               ("col_idx", "nvalid", "row_idx", "nvalid_t"))


def test_plan_removes_transpose_from_train_step_hlo():
    """Acceptance: with a SparsityPlan supplied, the jitted fused-kernel
    train step contains NO under-jit bcsr_transpose (its argsort lowers to
    HLO sort); the plan-less fallback does."""
    import jax.numpy as jnp

    from repro.launch.steps import make_train_step, spion_dryrun_tables
    from repro.optim import adamw_init

    cfg = get_config("spion-lra").replace(
        num_layers=1, d_ff=32, d_model=32, num_heads=2, num_kv_heads=2,
        vocab_size=64,
        spion=SpionConfig(enabled=True, block_size=16))
    L = 64
    tables = spion_dryrun_tables(cfg, L)
    bundle = build(cfg)
    params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.ndim >= 2 else x,
        bundle.init(jax.random.key(0)))
    opt = adamw_init(params)
    batch = {"tokens": jnp.zeros((2, L), jnp.int32),
             "labels": jnp.zeros((2, L), jnp.int32)}
    step = jax.jit(make_train_step(cfg, spion=True, sparse_kernel="fused"))
    hlo_plan = step.lower(params, opt, batch, jnp.int32(0), tables).as_text()
    assert "sort(" not in hlo_plan
    baseline = {k: tables[k] for k in ("col_idx", "nvalid", "block")}
    hlo_base = step.lower(params, opt, batch, jnp.int32(0), baseline).as_text()
    assert "sort(" in hlo_base


def test_lsh_attention_baseline_shape_and_locality():
    q = jax.random.normal(jax.random.key(0), (2, 128, 4, 16))
    out = lsh_attention(q, q, q, num_hashes=2, bucket_size=32)
    assert out.shape == q.shape
    assert not bool(jnp.isnan(out).any())


def test_spion_kwargs_gated_on_cfg_enabled(rng):
    """A sparse-phase state restored under a SPION-disabled config must NOT
    inject the tables into the step (regression: spion_kwargs ignored
    cfg.enabled, so restore-with-disabled-config silently trained sparse)."""
    ctl = _controller()
    st = SpionState()
    for _ in range(3):
        st = ctl.observe_epoch(st, _pooled(rng), np.array([1.0, 1.0]))
    assert st.phase == "sparse" and st.tables is not None
    assert ctl.spion_kwargs(st) is not None
    disabled = SpionController(
        SpionConfig(enabled=False, variant="cf", conv_filter_size=7,
                    block_size=16), causal=False, seq_len=64)
    assert disabled.spion_kwargs(st) is None
    # the capture path was already gated; keep them consistent
    assert disabled.capture_kwargs(SpionState()) is None


def test_from_py_arrays_without_tables_fails_loudly(rng):
    """Plan arrays supplied against a state dict with neither 'tables' nor
    'tables_meta' is a mismatched checkpoint pair; silently dropping the
    arrays used to resume the sparse phase with tables=None (dense steps
    forever). Must raise instead."""
    ctl = _controller()
    st = SpionState()
    for _ in range(3):
        st = ctl.observe_epoch(st, _pooled(rng), np.array([1.0, 1.0]))
    arrays = st.table_arrays()
    d = st.to_py(include_tables=False)
    del d["tables_meta"]
    with pytest.raises(ValueError, match="neither 'tables' nor 'tables_meta'"):
        SpionState.from_py(d, arrays)
    # arrays=None with a plain dense-state dict still restores fine
    dense = SpionState().to_py()
    assert SpionState.from_py(dense).tables is None


def test_plan_stats_carry_halo_extents(rng):
    """Pattern generation records the seq-parallel halo bounds (DESIGN.md
    §10) so the trainer can rebuild the sparse step with the static halo."""
    ctl = _controller()
    st = SpionState()
    for _ in range(3):
        st = ctl.observe_epoch(st, _pooled(rng), np.array([1.0, 1.0]))
    stats = st.plan_stats
    Ly = st.tables["col_idx"].shape[0]
    assert len(stats["col_extent_left"]) == Ly
    assert len(stats["col_extent_right"]) == Ly
    assert stats["halo"] == [max(stats["col_extent_left"]),
                             max(stats["col_extent_right"])]
    # round-trips through the JSON checkpoint channel unchanged
    st2 = SpionState.from_py(st.to_py())
    assert st2.plan_stats["halo"] == stats["halo"]
