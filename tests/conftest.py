"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the real
host device count (1 on CI); multi-device tests spawn subprocesses."""
import numpy as np
import pytest
from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
