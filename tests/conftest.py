"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the real
host device count (1 on CI); multi-device tests spawn subprocesses.

`hypothesis` is a dev dependency (declared in pyproject.toml); environments
without it (e.g. a bare container with only jax+numpy) fall back to a tiny
deterministic stub so the tier-1 suite still collects and runs — the stub
draws a fixed number of pseudo-random examples per @given test.
"""
import sys

import numpy as np
import pytest

try:
    from hypothesis import settings
except ModuleNotFoundError:  # pragma: no cover - exercised only without hypothesis
    import random
    import types

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda r: bool(r.getrandbits(1)))

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda r: seq[r.randrange(len(seq))])

    def _lists(elem, min_size=0, max_size=10, **_kw):
        return _Strategy(
            lambda r: [elem.draw(r) for _ in range(r.randint(min_size, max_size))])

    class _Settings:
        _profiles = {}
        _max_examples = 10

        def __init__(self, max_examples=None, deadline=None, **_kw):
            self.max_examples = max_examples

        def __call__(self, f):  # @settings(...) decorator form
            if self.max_examples:
                f._stub_max_examples = self.max_examples
            return f

        @classmethod
        def register_profile(cls, name, **kw):
            cls._profiles[name] = kw

        @classmethod
        def load_profile(cls, name):
            cls._max_examples = cls._profiles.get(name, {}).get("max_examples", 10)

    def _given(*strats, **kwstrats):
        def deco(f):
            def wrapper():
                r = random.Random(0)
                n = getattr(f, "_stub_max_examples", _Settings._max_examples)
                for _ in range(n):
                    drawn = [s.draw(r) for s in strats]
                    kdrawn = {k: s.draw(r) for k, s in kwstrats.items()}
                    f(*drawn, **kdrawn)
            # keep pytest from treating the drawn params as fixtures: the
            # wrapper's own (empty) signature must be what pytest inspects
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            wrapper.__module__ = f.__module__
            return wrapper
        return deco

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _st.lists = _lists
    _hyp.strategies = _st
    _hyp.given = _given
    _hyp.settings = _Settings
    _hyp.HealthCheck = types.SimpleNamespace(all=staticmethod(lambda: []))
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
    from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def free_port():
    """OS-assigned free TCP port for a jax.distributed coordinator."""
    import socket
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def run_distributed_case(code, nproc=2, timeout=600, env_extra=None):
    """Run `code` in `nproc` REAL jax.distributed CPU processes (gloo
    collectives, one device each). The snippet reads MP_PID/MP_NPROC/MP_PORT
    from the environment and must call repro.distributed.runtime.initialize
    itself. All processes must exit 0; returns their stdouts in pid order."""
    import pathlib
    import subprocess
    import sys as _sys
    root = str(pathlib.Path(__file__).resolve().parent.parent)
    port = free_port()
    procs = []
    for pid in range(nproc):
        env = {"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu",
               "PATH": "/usr/bin:/bin", "MP_PID": str(pid),
               "MP_NPROC": str(nproc), "MP_PORT": str(port),
               **(env_extra or {})}
        procs.append(subprocess.Popen(
            [_sys.executable, "-c", code], stdout=subprocess.PIPE,
            stderr=subprocess.PIPE, text=True, env=env, cwd=root))
    outs = []
    try:
        for p in procs:
            out, err = p.communicate(timeout=timeout)
            outs.append((p.returncode, out, err))
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    for pid, (rc, out, err) in enumerate(outs):
        assert rc == 0, f"process {pid} rc={rc}\n{err[-3000:]}"
    return [out for _, out, _ in outs]


def run_subprocess_case(code, devices=4):
    """Run a multi-device test snippet in a fresh interpreter with `devices`
    fake host devices (jax locks the device count at first init). Shared by
    the shard_map suites (test_sharded_attention / test_seq_parallel)."""
    import pathlib
    import subprocess
    import sys as _sys
    root = str(pathlib.Path(__file__).resolve().parent.parent)
    r = subprocess.run(
        [_sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": "src",
             "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
             "JAX_PLATFORMS": "cpu",
             "PATH": "/usr/bin:/bin"},
        cwd=root, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout
