"""Self-healing training (DESIGN.md §13), tier-1 half.

Two layers under test:

  1. The external FleetSupervisor — classify() verdicts from synthetic
     heartbeat payloads, and the spawn/watch/kill/respawn loop driven with
     real (but jax-free, millisecond-scale) subprocess workers.
  2. The in-loop divergence sentinel — a REAL tiny Trainer run where a
     chaos-injected NaN step triggers quarantine + restore of the pinned
     good checkpoint + data-window skip, and the stitched post-rollback
     losses match a run that never saw the poisoned batch.

The multi-process versions (2 real jax.distributed workers under the
supervisor, hang/kill/NaN legs) live in test_multiprocess.py behind
SPION_MP_TESTS=1.
"""
import json
import os
import sys
import time

import numpy as np
import pytest

from repro.distributed.supervisor import (FleetSupervisor, StepTracker,
                                          classify, free_port)


# -- classify: the liveness verdict --------------------------------------------

def test_classify_healthy_and_dead():
    tr = StepTracker()
    hb = {"ts": 100.0, "step": 5}
    assert classify(101.0, 90.0, hb, tr, dead_timeout=10.0,
                    hang_timeout=60.0) is None
    # stale ts -> dead, regardless of step history
    assert classify(111.0, 90.0, hb, tr, dead_timeout=10.0,
                    hang_timeout=60.0) == "dead"


def test_classify_missing_payload_counts_from_spawn():
    tr = StepTracker()
    # no heartbeat yet: grace window runs from spawn time, not from epoch 0
    assert classify(105.0, 100.0, None, tr, dead_timeout=10.0,
                    hang_timeout=60.0) is None
    assert classify(111.0, 100.0, None, tr, dead_timeout=10.0,
                    hang_timeout=60.0) == "dead"


def test_classify_hang_requires_frozen_step_with_fresh_ts():
    tr = StepTracker()
    # step advancing: never hung
    assert classify(10.0, 0.0, {"ts": 10.0, "step": 1}, tr,
                    dead_timeout=60.0, hang_timeout=5.0) is None
    assert classify(14.0, 0.0, {"ts": 14.0, "step": 2}, tr,
                    dead_timeout=60.0, hang_timeout=5.0) is None
    # frozen step, fresh ts (the beat thread still runs): hung after timeout
    assert classify(18.0, 0.0, {"ts": 18.0, "step": 2}, tr,
                    dead_timeout=60.0, hang_timeout=5.0) is None
    assert classify(20.0, 0.0, {"ts": 20.0, "step": 2}, tr,
                    dead_timeout=60.0, hang_timeout=5.0) == "hung"


def test_classify_hang_arms_only_after_first_step():
    """Before the worker publishes any step the payload is indistinguishable
    from a long first-step jit compile — the hang watchdog must NOT fire."""
    tr = StepTracker()
    for now in (10.0, 100.0, 1000.0):
        assert classify(now, 0.0, {"ts": now}, tr, dead_timeout=1e9,
                        hang_timeout=5.0) is None
    assert classify(1001.0, 0.0, {"ts": 1001.0, "step": 1}, tr,
                    dead_timeout=1e9, hang_timeout=5.0) is None
    assert classify(1010.0, 0.0, {"ts": 1010.0, "step": 1}, tr,
                    dead_timeout=1e9, hang_timeout=5.0) == "hung"


def test_classify_straggler_limit():
    tr = StepTracker()
    hb = {"ts": 10.0, "step": 3, "stragglers": 7}
    assert classify(11.0, 0.0, hb, tr, dead_timeout=60.0,
                    hang_timeout=60.0) is None  # off by default
    assert classify(11.0, 0.0, hb, tr, dead_timeout=60.0, hang_timeout=60.0,
                    straggler_limit=8) is None
    assert classify(11.0, 0.0, hb, tr, dead_timeout=60.0, hang_timeout=60.0,
                    straggler_limit=7) == "straggler"


def test_supervisor_backoff_capped():
    sup = FleetSupervisor(["true"], 1, "/tmp/x", backoff_base=1.0,
                          backoff_max=5.0)
    assert [sup.backoff(i) for i in range(5)] == [1.0, 2.0, 4.0, 5.0, 5.0]


# -- the respawn loop with real subprocess workers ----------------------------

def _mk_sup(tmp_path, code, nproc=1, **kw):
    kw.setdefault("poll_interval", 0.05)
    kw.setdefault("backoff_base", 0.01)
    kw.setdefault("backoff_max", 0.05)
    logs = []
    sup = FleetSupervisor([sys.executable, "-c", code], nproc, str(tmp_path),
                          log=logs.append, **kw)
    return sup, logs


def test_supervisor_clean_completion(tmp_path):
    """All workers exit 0 -> run() returns 0, no respawns; each worker saw
    its own SPION_PROCESS_ID/SPION_NUM_PROCESSES (written to marker files)."""
    code = ("import os\n"
            "d = os.environ['SPION_CKPT']\n"
            "i = os.environ['SPION_PROCESS_ID']\n"
            "n = os.environ['SPION_NUM_PROCESSES']\n"
            "open(os.path.join(d, 'saw_' + i), 'w').write(n)\n")
    sup, logs = _mk_sup(tmp_path, code, nproc=2)
    sup.env["SPION_CKPT"] = str(tmp_path)
    assert sup.run() == 0
    assert sup.respawns == 0
    assert (tmp_path / "saw_0").read_text() == "2"
    assert (tmp_path / "saw_1").read_text() == "2"
    assert any("SUPERVISOR done" in line for line in logs)


def test_supervisor_respawns_until_budget_exhausted(tmp_path):
    sup, logs = _mk_sup(tmp_path, "raise SystemExit(3)", max_respawns=2)
    assert sup.run() == 1
    assert sup.respawns == 2 and sup.generation == 2
    assert sum("SUPERVISOR fault" in line for line in logs) == 3
    assert any("exit=3" in line for line in logs)
    assert any("SUPERVISOR giveup" in line for line in logs)


def test_supervisor_respawn_heals_transient_crash(tmp_path):
    """Worker crashes in generation 0, succeeds in generation 1 (state via a
    marker file — the checkpoint-resume analogue at unit scale)."""
    code = ("import os\n"
            "m = os.path.join(os.environ['SPION_CKPT'],\n"
            "                 'gen0_' + os.environ['SPION_PROCESS_ID'])\n"
            "if not os.path.exists(m):\n"
            "    open(m, 'w').close()\n"
            "    raise SystemExit(1)\n")
    sup, logs = _mk_sup(tmp_path, code, nproc=2, max_respawns=3)
    sup.env["SPION_CKPT"] = str(tmp_path)
    assert sup.run() == 0
    assert sup.respawns == 1
    assert any("SUPERVISOR respawn gen=1" in line for line in logs)


def test_supervisor_detects_silent_death(tmp_path):
    """A worker that never heartbeats (sleeps forever) is declared dead
    after dead_timeout and the fleet is torn down."""
    sup, logs = _mk_sup(tmp_path, "import time; time.sleep(600)",
                        dead_timeout=0.4, hang_timeout=600.0, max_respawns=0)
    t0 = time.time()
    assert sup.run() == 1
    assert time.time() - t0 < 60  # did not wait out the sleep
    assert any("dead" in line for line in logs if "fault" in line)
    assert sup._procs == []  # fleet reaped


def test_supervisor_detects_hang_via_frozen_step(tmp_path):
    """A worker whose beat thread keeps ts fresh but whose step counter
    never advances is 'hung' — the verdict liveness-only monitoring cannot
    reach."""
    code = (
        "import json, os, time\n"
        "p = os.path.join(os.environ['SPION_CKPT'],\n"
        "                 'hb_' + os.environ['SPION_PROCESS_ID'])\n"
        "while True:\n"
        "    open(p + '.tmp', 'w').write(\n"
        "        json.dumps({'ts': time.time(), 'step': 4}))\n"
        "    os.replace(p + '.tmp', p)\n"
        "    time.sleep(0.05)\n")
    sup, logs = _mk_sup(tmp_path, code, dead_timeout=600.0, hang_timeout=0.4,
                        max_respawns=0)
    sup.env["SPION_CKPT"] = str(tmp_path)
    t0 = time.time()
    assert sup.run() == 1
    assert time.time() - t0 < 60
    assert any("hung" in line for line in logs if "fault" in line)


def test_supervisor_clears_stale_heartbeats_between_generations(tmp_path):
    """Generation N's dying heartbeat (old ts) must not read as an instant
    fault for generation N+1."""
    stale = tmp_path / "hb_0"
    with open(stale, "w") as f:
        json.dump({"ts": 1.0, "step": 99}, f)
    sup, _ = _mk_sup(tmp_path, "pass", dead_timeout=600.0)
    assert sup.run() == 0
    assert sup.respawns == 0


# -- divergence sentinel + rollback on a real (tiny) Trainer -------------------

def _cfg():
    from repro.configs import get_config
    from repro.configs.base import SpionConfig
    return get_config("spion-lra").replace(
        num_layers=2, d_ff=64, vocab_size=64,
        spion=SpionConfig(enabled=True, variant="cf", conv_filter_size=5,
                          block_size=16, alpha_quantile=0.85,
                          transition_tol=1e9, min_dense_epochs=1,
                          max_dense_epochs=2, kernel="jnp"))


def _data_fn(batch=4, seq=32, vocab=64):
    def fn(step):
        rng = np.random.default_rng(88_000 + step)
        toks = rng.integers(0, vocab, size=(batch, seq + 1))
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}
    return fn


def _trainer(tmp_path, name, **kw):
    from repro.distributed.fault import DivergenceSentinel
    from repro.launch.train import Trainer
    kw.setdefault("sentinel", DivergenceSentinel(spike=False))
    return Trainer(_cfg(), seq_len=32, batch=4, lr=1e-3, steps_per_epoch=4,
                   ckpt_dir=str(tmp_path / name), data_fn=_data_fn(), **kw)


def test_sentinel_rollback_end_to_end(tmp_path):
    """NaN-poisoned params at step 14 (checkpoints at 5/10, poisoned save at
    15): the sentinel rolls back to the pinned good step 10, quarantines the
    poisoned step-15 save, skips the data window [10, 14], and the stitched
    losses match a reference run that never saw the poisoned batches."""
    from repro.distributed.chaos import ChaosMonkey

    tr = _trainer(tmp_path, "heal", chaos=ChaosMonkey(nan_step=14))
    tr.train(20, ckpt_every=5, log_every=10**9, log=lambda *a: None)
    assert tr.rollback_count == 1
    assert tr.data_offset == 5            # window [10, 14] skipped
    assert tr.good_step == 20
    assert tr.step == 20                  # reached the target unattended
    ev = [e for e in tr.events if e["event"] == "rollback"]
    assert len(ev) == 1 and ev[0]["from_step"] == 14 and ev[0]["to_step"] == 10
    heal_dir = tmp_path / "heal"
    # the save taken AFTER the divergence point was quarantined, then the
    # replay re-committed a healthy step 15 under the canonical name
    assert (heal_dir / "quarantined_step_000000015").exists()
    assert (heal_dir / "step_000000020").exists()

    # reference: never poisoned, data stream with the window pre-skipped
    base = _data_fn()
    ref = _trainer(tmp_path, "ref")
    ref.data_fn = lambda step: base(step if step < 10 else step + 5)
    ref.train(20, ckpt_every=5, log_every=10**9, log=lambda *a: None)

    assert sorted(tr.loss_history) == sorted(ref.loss_history) == list(range(20))
    for s in range(20):
        v, r = tr.loss_history[s], ref.loss_history[s]
        assert np.isfinite(v)
        assert abs(v - r) <= 1e-3 + 1e-3 * abs(r), (s, v, r)


def test_rollback_resume_consistency(tmp_path):
    """data_offset is persisted in the checkpoint: a process respawned
    AFTER a rollback resumes with the skip window still in effect."""
    from repro.distributed.chaos import ChaosMonkey

    tr = _trainer(tmp_path, "resume", chaos=ChaosMonkey(nan_step=7))
    tr.train(15, ckpt_every=5, log_every=10**9, log=lambda *a: None)
    assert tr.rollback_count == 1 and tr.data_offset == 3  # window [5, 7]
    tr2 = _trainer(tmp_path, "resume")
    assert tr2.maybe_resume()
    assert tr2.step == 15 and tr2.data_offset == 3
    assert tr2.good_step == 15 and tr2.ckpt.pinned() == [15]


class _AlwaysDiverge:
    def observe(self, loss):
        return True

    def reset(self):
        pass


def test_rollback_without_good_checkpoint_fails_loudly(tmp_path):
    tr = _trainer(tmp_path, "nockpt", sentinel=_AlwaysDiverge())
    with pytest.raises(RuntimeError, match="no good checkpoint"):
        tr.train(10, ckpt_every=5, log_every=10**9, log=lambda *a: None)


def test_persistent_divergence_hard_fails_after_max_rollbacks(tmp_path):
    tr = _trainer(tmp_path, "loop", max_rollbacks=2)
    tr.train(5, ckpt_every=5, log_every=10**9, log=lambda *a: None)
    assert tr.good_step == 5
    tr.sentinel = _AlwaysDiverge()
    with pytest.raises(RuntimeError, match="not recoverable"):
        tr.train(5, ckpt_every=5, log_every=10**9, log=lambda *a: None)
    assert tr.rollback_count == 3  # 2 allowed + the one that raised


def test_trainer_heartbeat_payload_reaches_supervisor_format(tmp_path):
    """The heartbeat file a Trainer writes parses into exactly what
    classify() consumes: fresh ts, advancing step, phase."""
    from repro.distributed.fault import Heartbeat

    tr = _trainer(tmp_path, "hb", heartbeat_interval=0.0)
    tr.train(3, ckpt_every=0, log_every=10**9, log=lambda *a: None)
    hb = Heartbeat.read(os.path.join(str(tmp_path / "hb"), "hb_0"))
    assert hb is not None and hb["step"] == 3
    assert hb["phase"] == tr.spion_state.phase
    assert "stragglers" in hb
    st = StepTracker()
    assert classify(hb["ts"], 0.0, hb, st, dead_timeout=60.0,
                    hang_timeout=60.0) is None
    assert st.step == 3


@pytest.mark.skipif(os.environ.get("SPION_MP_TESTS") == "1", reason="covered "
                    "by the full supervisor e2e in test_multiprocess.py")
def test_supervise_cli_rejects_missing_worker_cmd():
    from repro.launch import supervise
    with pytest.raises(SystemExit):
        supervise.main(["--nproc", "1", "--ckpt-dir", "/tmp/x"])


def test_free_port_is_bindable():
    import socket
    p = free_port()
    with socket.socket() as s:
        s.bind(("localhost", p))
