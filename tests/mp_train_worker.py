"""Multi-process training worker for the fault-tolerance suite and the
`faultrecovery` bench — NOT a pytest module (no test_ prefix).

One OS process of an N-process `jax.distributed` CPU job. The launcher
(tests/test_multiprocess.py, benchmarks/fault_recovery.py, or the
self-healing supervisor `python -m repro.launch.supervise`) spawns N of
these with a shared coordinator port and checkpoint dir, optionally arming
SPION_CHAOS_* to kill/hang/NaN-poison one mid-run. Deterministic by
construction: params from a fixed seed, data step-indexed (data_fn), so any
two runs — whatever their process count or crash history — walk the same
global batch sequence and their per-step losses are comparable.

--pid/--nproc/--port are optional: when the supervisor launches us it sets
SPION_COORDINATOR/SPION_NUM_PROCESSES/SPION_PROCESS_ID instead and
runtime.initialize() picks those up.

Prints one `LOSS,<step>,<value>` line per step (process 0 only) LIVE as
steps complete — a killed generation keeps the lines it earned, and a
launcher stitches runs by letting later lines for the same step overwrite
earlier ones (exactly the rollback-replay semantics). Ends with
`WORKER_TIMING steps=<n> seconds=<s>` and a final
`WORKER_DONE step=<n> phase=<p> density=<d> preempted=<0|1> rollbacks=<r>`.

--skip-window G:D builds the divergence-rollback *reference* data stream:
data index = step for step < G, step + (D - G + 1) for step >= G — the
sequence a healed run settles on after rolling back to G and skipping the
poisoned window [G, D].
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pid", type=int, default=None)
    ap.add_argument("--nproc", type=int, default=None)
    ap.add_argument("--port", type=int, default=None)
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--target-step", type=int, required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--steps-per-epoch", type=int, default=4)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--heartbeat-interval", type=float, default=0.5)
    ap.add_argument("--skip-window", default=None, metavar="G:D",
                    help="reference-run data stream for a rollback that "
                         "skipped window [G, D]")
    args = ap.parse_args()

    from repro.distributed import runtime
    coordinator = f"localhost:{args.port}" if args.port is not None else None
    runtime.initialize(coordinator, args.nproc, args.pid)

    from repro.configs import get_config
    from repro.configs.base import SpionConfig
    from repro.distributed.fault import DivergenceSentinel
    from repro.launch.mesh import make_distributed_mesh
    from repro.launch.train import Trainer

    # tiny but real: dense phase -> forced transition at epoch 2 -> sparse
    # phase; jnp kernel (this suite proves the fault protocol, not Pallas)
    cfg = get_config("spion-lra").replace(
        num_layers=args.layers, d_ff=64, vocab_size=64,
        spion=SpionConfig(enabled=True, variant="cf", conv_filter_size=5,
                          block_size=16, alpha_quantile=0.85,
                          transition_tol=1e9, min_dense_epochs=1,
                          max_dense_epochs=2, kernel="jnp"))

    B, S, vocab = args.batch, args.seq_len, cfg.vocab_size

    def data_fn(step):
        # step-indexed and process-independent: the SAME global batch on
        # every process and every (re)incarnation of the job
        rng = np.random.default_rng(77_000 + step)
        toks = rng.integers(0, vocab, size=(B, S + 1))
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    if args.skip_window:
        g, d = (int(v) for v in args.skip_window.split(":"))
        base_fn, shift = data_fn, d - g + 1

        def data_fn(step):  # noqa: F811 - deliberate reference-stream wrap
            return base_fn(step if step < g else step + shift)

    def on_step(step, loss):
        # LIVE per-step loss: a killed/hung generation keeps the lines it
        # already earned; replayed steps print again and the launcher's
        # dict-stitching keeps the last occurrence
        if runtime.is_coordinator():
            print(f"LOSS,{step},{loss:.8f}", flush=True)

    mesh = make_distributed_mesh()
    tr = Trainer(cfg, seq_len=S, batch=B, lr=1e-3,
                 steps_per_epoch=args.steps_per_epoch,
                 ckpt_dir=args.ckpt_dir, mesh=mesh, data_fn=data_fn,
                 heartbeat_interval=args.heartbeat_interval,
                 # NaN/inf detection only: the chaos tests poison params
                 # deterministically, and the tiny-model loss curve is too
                 # jumpy for a meaningful spike threshold at this scale
                 sentinel=DivergenceSentinel(spike=False),
                 step_callback=on_step)
    tr.install_preemption_handler()
    tr.maybe_resume()
    start = tr.step
    t0 = time.time()
    losses = tr.train(args.target_step - start,
                      ckpt_every=args.ckpt_every, log_every=10**9,
                      log=lambda *a, **k: None)
    dt = time.time() - t0
    if runtime.is_coordinator():
        # wall clock over the whole loop (jit compile included) — the
        # faultrecovery bench compares legs run under the same harness
        print(f"WORKER_TIMING steps={len(losses)} seconds={dt:.3f}")
    print(f"WORKER_DONE step={tr.step} phase={tr.spion_state.phase} "
          f"density={tr.spion_state.density} "
          f"preempted={int(tr.preempted)} rollbacks={tr.rollback_count}",
          flush=True)


if __name__ == "__main__":
    main()
