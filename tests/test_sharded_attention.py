"""Sharded fused sparse attention: shard_map dispatch correctness, the
mesh-aware "auto" resolution, loud-failure guards, and the sparse
train-step compile proof on a 2-axis (data, model) mesh.

All multi-device checks run in subprocesses with 4 fake host devices (jax
locks the device count at first init — same pattern as
tests/test_distributed.py)."""
import pytest
from conftest import run_subprocess_case as _run_sub

from repro.configs import get_config
from repro.distributed.sharding import kernel_shard_axes
from repro.launch.mesh import make_mesh
from repro.models.attention import resolve_sparse_kernel


def test_kernel_shard_axes_choice():
    mesh = make_mesh((1,), ("data",))  # single device: nothing to shard
    assert kernel_shard_axes(mesh, 8, 4) == (None, None)


def test_dispatch_not_keyed_on_model_config(rng):
    """The kernel jit is keyed only on (causal, sliding_window, block, fused,
    interpret) — unrelated ModelConfig changes (act_shard, ar_bf16, bench
    sweeps over d_ff) must NOT retrace it."""
    import jax
    import numpy as np

    from repro.core.sparse_attention import bcsr_from_blockmask
    from repro.kernels.ops import _dispatch, spion_attention_kernel

    cfg = get_config("spion-lra")
    S, block, hd = 64, 32, 16
    q = jax.random.normal(jax.random.key(0), (2, S, 2, hd))
    kv = jax.random.normal(jax.random.key(1), (2, S, 2, hd))
    mask = np.random.default_rng(0).random((2, 2)) < 0.9
    np.fill_diagonal(mask, True)
    b = bcsr_from_blockmask(mask, block)
    spion_attention_kernel(cfg, q, kv, kv, b, interpret=True)
    n0 = _dispatch._cache_size()
    for variant in (cfg.replace(act_shard="d"), cfg.replace(ar_bf16=True),
                    cfg.replace(d_ff=4096), cfg.replace(scan_unroll=8)):
        spion_attention_kernel(variant, q, kv, kv, b, interpret=True)
    assert _dispatch._cache_size() == n0, \
        "unrelated config fields retraced the kernel jit"
    # kernel statics still key it
    spion_attention_kernel(cfg.replace(causal=True), q, kv, kv, b,
                           interpret=True)
    assert _dispatch._cache_size() == n0 + 1


def test_resolve_sparse_kernel_meshless():
    cfg = get_config("spion-lra")
    # no mesh, CPU backend -> jnp (unchanged single-device behaviour)
    assert resolve_sparse_kernel(cfg, 4, 4) == "jnp"
    import dataclasses
    forced = cfg.replace(spion=dataclasses.replace(cfg.spion, kernel="fused"))
    assert resolve_sparse_kernel(forced, 4, 4) == "fused"


AXES_CODE = """
from repro.distributed.sharding import kernel_shard_axes, kernel_pspecs
from repro.launch.mesh import make_mesh
from jax.sharding import PartitionSpec as P
mesh = make_mesh((2, 2), ("data", "model"))
# batch and KV both divide -> both shard
assert kernel_shard_axes(mesh, 4, 2) == (("data",), "model")
# KV indivisible -> clean fallback to batch-only sharding
assert kernel_shard_axes(mesh, 4, 3) == (("data",), None)
# batch indivisible, KV divides -> model-only
assert kernel_shard_axes(mesh, 3, 2) == (None, "model")
# nothing divides
assert kernel_shard_axes(mesh, 3, 3) == (None, None)
q, kv, tab = kernel_pspecs(mesh, 4, 2)
assert q == P(("data",), "model", None, None, None)
assert kv == P(("data",), "model", None, None)
assert tab == P()
# pod composes with data greedily, dropping axes that stop dividing
mesh3 = make_mesh((2, 2, 1), ("pod", "data", "model"))
assert kernel_shard_axes(mesh3, 4, 4) == (("pod", "data"), None)
assert kernel_shard_axes(mesh3, 2, 4) == (("pod",), None)
print("OK")
"""


# forward + grads of the shard_map-fused path vs the jnp BCSR path (the
# tolerances of tests/test_kernels.py: fwd 2e-5, grads 1e-3), plus bitwise
# agreement of the sharded forward with the meshless fused kernel — the
# shard boundary must not change the math at all.
MATCH_CODE = """
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.core.sparse_attention import (BCSR, bcsr_attention,
                                         bcsr_from_blockmask,
                                         build_sparsity_plan)
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_mesh
from repro.models.attention import resolve_sparse_kernel, spion_sparse_attention

mesh = make_mesh((2, 2), ("data", "model"))
S, block, hd, B = 128, 32, 32, 4
n = S // block
rng = np.random.default_rng(0)

# (causal, sliding_window, H, KV, with_plan):
#   - encoder, no plan
#   - causal + plan
#   - causal + sliding window + plan
#   - GQA with KV sharded over model (KV=2 divides |model|=2)
#   - GQA with KV UNsharded (KV=3 indivisible -> batch-only sharding)
CASES = [(False, None, 4, 4, False),
         (True, None, 4, 4, True),
         (True, 96, 2, 2, True),
         (True, None, 4, 2, True),
         (True, None, 3, 3, False)]

for causal, sw, H, KV, with_plan in CASES:
    cfg = get_config("spion-lra").replace(
        causal=causal, sliding_window=sw, num_heads=H, num_kv_heads=KV,
        spion=dataclasses.replace(get_config("spion-lra").spion,
                                  block_size=block))
    mask = rng.random((n, n)) < 0.5
    np.fill_diagonal(mask, True)
    b = bcsr_from_blockmask(mask, block)
    layer = {"col_idx": b.col_idx, "nvalid": b.nvalid, "block": block}
    if with_plan:
        p = build_sparsity_plan(b.col_idx, b.nvalid, block, ncb=n)
        layer["row_idx"] = p.tables["row_idx"][0]
        layer["nvalid_t"] = p.tables["nvalid_t"][0]
    key = jax.random.key(hash((causal, H, KV)) % 1000)
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    gout = jax.random.normal(jax.random.fold_in(key, 3), (B, S, H, hd))

    def loss(q, k, v, impl):
        c = cfg.replace(spion=dataclasses.replace(cfg.spion, kernel=impl))
        return jnp.sum(spion_sparse_attention(c, q, k, v, layer) * gout)

    with mesh_context(mesh):
        assert resolve_sparse_kernel(cfg, B, KV) == "fused", (causal, H, KV)
        o_sh = spion_sparse_attention(cfg, q, k, v, layer)
        g_sh = jax.grad(lambda *a: loss(*a, "auto"), argnums=(0, 1, 2))(q, k, v)
    o_local = spion_sparse_attention(
        cfg.replace(spion=dataclasses.replace(cfg.spion, kernel="fused")),
        q, k, v, layer)
    o_jnp = bcsr_attention(cfg, q, k, v, BCSR(b.col_idx, b.nvalid, block, S))
    g_jnp = jax.grad(lambda *a: loss(*a, "jnp"), argnums=(0, 1, 2))(q, k, v)

    tag = f"causal={causal} sw={sw} H={H} KV={KV} plan={with_plan}"
    assert bool(jnp.all(o_sh == o_local)), f"sharded fwd not bitwise: {tag}"
    np.testing.assert_allclose(np.asarray(o_sh), np.asarray(o_jnp),
                               atol=2e-5, err_msg=f"fwd vs jnp: {tag}")
    for name, a, w in zip("qkv", g_sh, g_jnp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w), atol=1e-3,
                                   err_msg=f"d{name} vs jnp: {tag}")
print("OK")
"""


# loud-failure guards: a bare fused kernel call under a multi-device mesh,
# the forward-only 3-kernel pipeline, and forcing "fused" when no mesh axis
# divides must all raise instead of running silently replicated.
GUARD_CODE = """
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.core.sparse_attention import bcsr_from_blockmask
from repro.distributed.sharding import mesh_context
from repro.kernels.block_sparse_attn import fused_block_sparse_attention
from repro.kernels.ops import spion_attention_kernel
from repro.launch.mesh import make_mesh
from repro.models.attention import resolve_sparse_kernel, spion_sparse_attention

mesh = make_mesh((2, 2), ("data", "model"))
S, block, hd = 64, 32, 16
n = S // block
rng = np.random.default_rng(0)
mask = rng.random((n, n)) < 0.8
np.fill_diagonal(mask, True)
b = bcsr_from_blockmask(mask, block)
col = jnp.maximum(b.col_idx, 0)
cfg = get_config("spion-lra")
q = jax.random.normal(jax.random.key(0), (2, S, 2, hd))
kv = jax.random.normal(jax.random.key(1), (2, S, 2, hd))
q5 = jax.random.normal(jax.random.key(2), (4, 1, S, hd))
kv4 = jax.random.normal(jax.random.key(3), (4, S, hd))

with mesh_context(mesh):
    # bare kernel call: no shard_map wrapper -> loud failure
    try:
        fused_block_sparse_attention(q5, kv4, kv4, col, b.nvalid, block=block,
                                     interpret=True)
        raise SystemExit("bare fused call under mesh must raise")
    except RuntimeError as e:
        assert "shard_map" in str(e), e
    # the fused kernel is the only wrapper path — the legacy 3-kernel
    # escape hatch is gone for good
    try:
        spion_attention_kernel(cfg, q, kv, kv, b, fused=False, interpret=True)
        raise SystemExit("fused kwarg must no longer exist")
    except TypeError as e:
        assert "fused" in str(e), e
    # wrapper under mesh routes through shard_map and works
    out_m = spion_attention_kernel(cfg, q, kv, kv, b, interpret=True)
    assert out_m.shape == q.shape
    # nothing divides (B=3, KV=3 on a 2x2 mesh): auto falls back to jnp,
    # forcing fused raises
    q3 = jax.random.normal(jax.random.key(4), (3, S, 3, hd))
    kv3 = jax.random.normal(jax.random.key(5), (3, S, 3, hd))
    assert resolve_sparse_kernel(cfg, 3, 3) == "jnp"
    forced = cfg.replace(spion=dataclasses.replace(cfg.spion, kernel="fused"))
    layer = {"col_idx": b.col_idx, "nvalid": b.nvalid, "block": block}
    try:
        spion_sparse_attention(forced, q3, kv3, kv3, layer)
        raise SystemExit("forced fused with no shardable axis must raise")
    except RuntimeError as e:
        assert "no mesh axis" in str(e), e
# outside the mesh the same bare call works (single-shard op)
out = fused_block_sparse_attention(q5, kv4, kv4, col, b.nvalid, block=block,
                                   interpret=True)
assert out.shape == q5.shape
print("OK")
"""


# the sparse train step compiles on the 2-axis (data, model) production-mesh
# layout with the shard_map kernel visible in the lowered HLO, and the
# dry-run sparse cell records the mesh-aware resolution.
TRAIN_STEP_CODE = """
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_train_step, spion_dryrun_tables
from repro.models.registry import build
from repro.optim import adamw_init

mesh = make_mesh((2, 2), ("data", "model"))
L, B = 64, 4
cfg = get_config("spion-lra").reduced()
cfg = cfg.replace(num_heads=4, num_kv_heads=2, head_dim=16,
                  spion=dataclasses.replace(cfg.spion, block_size=16))
bundle = build(cfg)
params = jax.tree_util.tree_map(
    lambda x: x.astype(jnp.float32) if x.ndim >= 2 else x,
    bundle.init(jax.random.key(0)))
opt = adamw_init(params)
batch = {"tokens": jnp.zeros((B, L), jnp.int32),
         "labels": jnp.zeros((B, L), jnp.int32)}
tables = spion_dryrun_tables(cfg, L)
step = make_train_step(cfg, spion=True, sparse_kernel="auto")
args = (params, opt, batch, jnp.int32(0), tables)
with mesh_context(mesh):
    jaxpr = str(jax.make_jaxpr(step)(*args))
    assert "shard_map" in jaxpr, "auto must route through shard_map"
    assert "pallas_call" in jaxpr, "auto must keep the Pallas kernel"
    lowered = jax.jit(step).lower(*args)
    hlo = lowered.as_text()
    # shard_map manual partitioning marker in the lowered module; on TPU the
    # kernel itself additionally lowers to a tpu_custom_call
    assert "SPMDFullToShardShape" in hlo, "shard_map missing from HLO"
    if jax.default_backend() == "tpu":
        assert "tpu_custom_call" in hlo
    lowered.compile()   # the compile-proof on the sharded mesh
    # one real step executes and trains through the sharded kernel
    p2, _, metrics = jax.jit(step)(*args)
    assert bool(jnp.isfinite(metrics["loss"]))
    delta = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x))), jax.tree_util.tree_map(
            jnp.subtract, p2, params), 0.0)
    assert delta > 0.0, "params must move through the sharded sparse step"
print("OK")
"""


# every SPION-able model family threads the mesh-aware dispatch: the encdec
# decoder self-attention and the hybrid shared-attention block go through
# the same spion_sparse_attention, so under the mesh their sparse prefill
# must carry shard_map+pallas_call in the jaxpr, match the jnp path, and
# keep working plan-less (col_idx/nvalid only -> under-jit transpose
# fallback inside the shard).
FAMILIES_CODE = """
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_prefill_step, spion_dryrun_tables
from repro.models.registry import build

mesh = make_mesh((2, 2), ("data", "model"))
L, B = 64, 4
for arch in ("whisper-tiny", "zamba2-1.2b"):
    cfg = get_config(arch).reduced()
    cfg = cfg.replace(spion=dataclasses.replace(cfg.spion, enabled=True,
                                                block_size=16))
    n_spion = (max(cfg.num_layers // cfg.hybrid_attn_every, 1)
               if cfg.family == "hybrid" else cfg.num_layers)
    tables = spion_dryrun_tables(cfg, L, n_spion)
    bundle = build(cfg)
    params = bundle.init(jax.random.key(0))
    batch = {"tokens": jnp.zeros((B, L), jnp.int32)}
    if cfg.family in ("audio", "encdec"):
        batch["frames"] = jnp.zeros((B, L, cfg.d_model), cfg.dtype)
    prefill = make_prefill_step(cfg, spion=True)
    with mesh_context(mesh):
        jaxpr = str(jax.make_jaxpr(prefill)(params, batch, tables))
        assert "shard_map" in jaxpr and "pallas_call" in jaxpr, arch
        o_sh = jax.jit(prefill)(params, batch, tables)
        # plan-less fallback still runs through the sharded kernel
        base = {k: tables[k] for k in ("col_idx", "nvalid", "block")}
        o_base = jax.jit(prefill)(params, batch, base)
        cfgj = cfg.replace(spion=dataclasses.replace(cfg.spion, kernel="jnp"))
        o_jnp = jax.jit(make_prefill_step(cfgj, spion=True))(params, batch,
                                                             tables)
    np.testing.assert_allclose(np.asarray(o_sh, np.float32),
                               np.asarray(o_base, np.float32), atol=5e-2,
                               err_msg=f"plan vs plan-less: {arch}")
    np.testing.assert_allclose(np.asarray(o_sh, np.float32),
                               np.asarray(o_jnp, np.float32), atol=5e-2,
                               err_msg=f"sharded-fused vs jnp: {arch}")
print("OK")
"""


DRYRUN_CELL_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, tempfile
import jax
jax.devices()   # lock the 4-device count before dryrun's 512 flag could bite
from repro.configs.base import SHAPES, ShapeSpec
from repro.configs import get_config
from repro.launch import dryrun
from repro.launch.mesh import make_mesh

SHAPES["tiny_train"] = ShapeSpec("tiny_train", 64, 4, "train")
cfg = get_config("spion-lra").reduced()
cfg = cfg.replace(num_heads=4, num_kv_heads=2, head_dim=16,
                  spion=dataclasses.replace(cfg.spion, block_size=16))
mesh = make_mesh((2, 2), ("data", "model"))
with tempfile.TemporaryDirectory() as d:
    rec = dryrun.run_cell("spion-lra", "tiny_train", False, "sparse", d,
                          verbose=False, cfg_override=cfg, skip_costs=True,
                          mesh_override=mesh)
assert rec["status"] == "ok", rec
assert rec["sparse_kernel"] == "fused", rec
print("OK")
"""


@pytest.mark.parametrize("code", [AXES_CODE, MATCH_CODE, GUARD_CODE,
                                  TRAIN_STEP_CODE, FAMILIES_CODE,
                                  DRYRUN_CELL_CODE],
                         ids=["axes", "match", "guards", "train_step",
                              "families", "dryrun_cell"])
def test_sharded_subprocess(code):
    assert "OK" in _run_sub(code)
