"""Distribution layer: sharding rules/sanitiser (in-process) and
multi-device pipeline/collectives (subprocess with fake devices — jax locks
the device count at first init, so these re-exec)."""
import subprocess
import sys

import jax
import numpy as np
import pytest
from hypothesis import given, strategies as st
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import spec_for_path


def test_param_spec_rules():
    assert spec_for_path("layers/attn/wq", 3) == P(None, None, "model")
    assert spec_for_path("layers/attn/wo", 3) == P(None, "model", None)
    assert spec_for_path("layers/moe/experts/w_in", 4) == P(None, "model", None, None)
    assert spec_for_path("tok_embed/w", 2) == P("model", None)
    assert spec_for_path("layers/attn_norm/scale", 2) == P()
    assert spec_for_path("layers/mlp/w_out", 3) == P(None, "model", None)
    assert spec_for_path("layers/tm/w_r", 3) == P(None, None, "model")


def _run_sub(code):
    import pathlib
    root = str(pathlib.Path(__file__).resolve().parent.parent)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={"PYTHONPATH": "src",
                                       "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
                                       # fake devices are host-platform; pin cpu so
                                       # jax never probes other backends (hangs on
                                       # network-less CI sandboxes)
                                       "JAX_PLATFORMS": "cpu",
                                       "PATH": "/usr/bin:/bin"},
                       cwd=root, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    return r.stdout


SANITIZE_CODE = """
import jax
from jax.sharding import PartitionSpec as P
from repro.distributed.sharding import sanitize_spec
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2), ("data", "model"))
assert sanitize_spec(mesh, P("data", "model"), (4, 6)) == P("data", "model")
assert sanitize_spec(mesh, P("data", "model"), (3, 6)) == P(None, "model")
assert sanitize_spec(mesh, P(("data", "model"),), (6,)) == P(("data",),)
assert sanitize_spec(mesh, P(("data", "model"),), (8,)) == P(("data", "model"),)
assert sanitize_spec(mesh, P(None, "model"), (4, 5)) == P()
print("OK")
"""


PIPELINE_CODE = """
import jax, jax.numpy as jnp
from repro.distributed.pipeline import pipeline_apply
from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("pod",))
S, d = 4, 8
ws = jnp.stack([jnp.eye(d) * (i + 1) for i in range(S)])
x = jax.random.normal(jax.random.key(0), (8, d))
out = pipeline_apply(mesh, "pod", lambda w, a: a @ w, ws, x, n_micro=4)
ref = x
for i in range(S):
    ref = ref @ ws[i]
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-5, err
# gradients flow through the pipeline
g = jax.grad(lambda ws: pipeline_apply(mesh, "pod", lambda w, a: a @ w, ws, x, 2).sum())(ws)
assert float(jnp.max(jnp.abs(g))) > 0
print("OK")
"""


COLLECTIVES_CODE = """
import jax, jax.numpy as jnp
from repro.distributed.collectives import compressed_grad_sync, hierarchical_grad_sync
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2), ("pod", "data"))
g = {"w": jnp.ones((8, 8)) * 0.25}
s = compressed_grad_sync(mesh, g, axes=("data",))
assert abs(float(s["w"][0, 0]) - 0.5) < 0.01
h = hierarchical_grad_sync(mesh, g)
assert abs(float(h["w"][0, 0]) - 1.0) < 0.02
print("OK")
"""


@pytest.mark.parametrize("code", [SANITIZE_CODE, PIPELINE_CODE, COLLECTIVES_CODE],
                         ids=["sanitize", "pipeline", "collectives"])
def test_multidevice_subprocess(code):
    assert "OK" in _run_sub(code)
