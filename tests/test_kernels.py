"""Pallas kernel validation (interpret mode): shape/dtype sweeps vs the
ref.py pure-jnp oracles and vs the BCSR jnp path, plus jax.grad checks of
the fused kernel's custom VJP against the differentiable dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.configs import get_config
from repro.core.sparse_attention import (bcsr_attention, bcsr_from_blockmask,
                                         bcsr_transpose, build_sparsity_plan,
                                         host_transpose_tables)
from repro.kernels import ref
from repro.kernels.block_sparse_attn import fused_block_sparse_attention
from repro.kernels.dispatch import (COMPILED_BACKENDS, KernelConfig,
                                    default_interpret)
from repro.kernels.ops import spion_attention_kernel


def _bcsr(rng, n, block, density=0.5):
    mask = rng.random((n, n)) < density
    np.fill_diagonal(mask, True)
    return bcsr_from_blockmask(mask, block)


SWEEP = [
    # (S, hd, block, dtype, causal, sw)
    (128, 32, 32, jnp.float32, False, None),
    (128, 32, 32, jnp.float32, True, None),
    (256, 64, 64, jnp.float32, True, 96),
    (128, 16, 32, jnp.bfloat16, True, None),
    (64, 128, 32, jnp.float32, False, None),
]

# 3-kernel-vs-fused parity after the collapse (DESIGN.md §15): the retained
# reference pipeline (ref.sddmm_ref -> ref.sparse_softmax_ref -> ref.spmm_ref,
# the demoted paper-faithful path) must match the production fused kernel on
# causal / sliding-window / GQA patterns.
PARITY_SWEEP = [
    # (causal, sw, G)
    (False, None, 1),
    (True, None, 1),
    (True, 96, 1),
    (False, 96, 2),
    (True, None, 4),     # GQA: 4 query heads share each kv head
    (False, None, 2),
]


@pytest.mark.parametrize("causal,sw,G", PARITY_SWEEP)
def test_ref_pipeline_vs_fused_parity(causal, sw, G, rng):
    """The demoted 3-kernel pipeline, staged explicitly through its three
    oracles, agrees with the single-pass fused kernel (interpreter mode so
    this holds the line on CPU CI)."""
    S, hd, block, N = 256, 32, 32, 2
    q = jax.random.normal(jax.random.key(0), (N, G, S, hd))
    k = jax.random.normal(jax.random.key(1), (N, S, hd))
    v = jax.random.normal(jax.random.key(2), (N, S, hd))
    b = _bcsr(rng, S // block, block)
    col = jnp.maximum(b.col_idx, 0)
    out = fused_block_sparse_attention(q, k, v, col, b.nvalid, block=block,
                                       causal=causal, sliding_window=sw,
                                       interpret=True)
    for g in range(G):
        s = ref.sddmm_ref(q[:, g], k, b.col_idx, block=block, causal=causal,
                          sliding_window=sw)
        p = ref.sparse_softmax_ref(s, b.col_idx, block=block, seq_len=S,
                                   causal=causal, sliding_window=sw)
        want = ref.spmm_ref(p, v, b.col_idx)
        np.testing.assert_allclose(np.asarray(out[:, g], np.float32),
                                   np.asarray(want, np.float32), atol=3e-5,
                                   err_msg=f"group {g}")


@pytest.mark.parametrize("S,hd,block,dtype,causal,sw", SWEEP)
def test_fused_kernel_vs_ref(S, hd, block, dtype, causal, sw, rng):
    N, G = 2, 2
    q = jax.random.normal(jax.random.key(0), (N, G, S, hd), dtype)
    k = jax.random.normal(jax.random.key(1), (N, S, hd), dtype)
    v = jax.random.normal(jax.random.key(2), (N, S, hd), dtype)
    b = _bcsr(rng, S // block, block)
    col = jnp.maximum(b.col_idx, 0)
    out = fused_block_sparse_attention(q, k, v, col, b.nvalid, block=block,
                                       causal=causal, sliding_window=sw,
                                       interpret=True)
    want = jnp.stack([
        ref.fused_ref(q[:, g], k, v, b.col_idx, block=block, causal=causal,
                      sliding_window=sw) for g in range(G)], axis=1)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=6e-2 if dtype == jnp.bfloat16 else 3e-5)


GRAD_SWEEP = [
    # (S, hd, block, causal, sw, G)
    (128, 32, 32, False, None, 1),   # encoder
    (128, 32, 32, True, None, 1),    # causal LM
    (256, 64, 64, True, 96, 1),      # causal + sliding window
    (128, 16, 32, True, None, 4),    # GQA: 4 query heads per kv head
]


@pytest.mark.parametrize("S,hd,block,causal,sw,G", GRAD_SWEEP)
def test_fused_vjp_grads_vs_dense_ref(S, hd, block, causal, sw, G, rng):
    """jax.grad of the fused custom-VJP kernel == grad of the differentiable
    jnp reference (dense path masked to the active pattern) within 1e-3."""
    N = 2
    q = jax.random.normal(jax.random.key(0), (N, G, S, hd))
    k = jax.random.normal(jax.random.key(1), (N, S, hd))
    v = jax.random.normal(jax.random.key(2), (N, S, hd))
    b = _bcsr(rng, S // block, block)
    col = jnp.maximum(b.col_idx, 0)
    gout = jax.random.normal(jax.random.key(3), (N, G, S, hd))

    def loss_fused(q, k, v):
        o = fused_block_sparse_attention(q, k, v, col, b.nvalid, block=block,
                                         causal=causal, sliding_window=sw,
                                         interpret=True)
        return jnp.sum(o * gout)

    def loss_ref(q, k, v):
        o = jnp.stack([ref.fused_ref(q[:, g], k, v, b.col_idx, block=block,
                                     causal=causal, sliding_window=sw)
                       for g in range(G)], axis=1)
        return jnp.sum(o * gout)

    got = jax.grad(loss_fused, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for name, a, w in zip("qkv", got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w), atol=1e-3,
                                   err_msg=f"d{name} mismatch")


def test_fused_vjp_under_jit_and_dtype(rng):
    """The custom VJP composes with jit; bf16 inputs get bf16 cotangents."""
    S, hd, block = 128, 32, 32
    q = jax.random.normal(jax.random.key(0), (2, 2, S, hd), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (2, S, hd), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (2, S, hd), jnp.bfloat16)
    b = _bcsr(rng, S // block, block)
    col = jnp.maximum(b.col_idx, 0)

    @jax.jit
    def g(q, k, v):
        return jax.grad(lambda q, k, v: jnp.sum(
            fused_block_sparse_attention(q, k, v, col, b.nvalid, block=block,
                                         causal=True, interpret=True)
            .astype(jnp.float32)), argnums=(0, 1, 2))(q, k, v)

    dq, dk, dv = g(q, k, v)
    assert dq.dtype == jnp.bfloat16 and dk.dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(dq, np.float32)).all()
    assert float(jnp.max(jnp.abs(dv.astype(jnp.float32)))) > 0


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("n", [1, 3, 8])
def test_bcsr_transpose_roundtrip(seed, n):
    """Property: transpose . transpose == identity on the active block set."""
    r = np.random.default_rng(seed)
    mask = r.random((n, n)) < r.uniform(0.1, 0.9)
    np.fill_diagonal(mask, True)
    b = bcsr_from_blockmask(mask, 8)

    def dense_of(idx, nv, ncols):
        idx, nv = np.asarray(idx), np.asarray(nv)
        out = np.zeros((idx.shape[0], ncols), bool)
        for i in range(idx.shape[0]):
            out[i, idx[i, : nv[i]]] = True
        return out

    row_idx, nvt = bcsr_transpose(b.col_idx, b.nvalid, ncb=n)
    assert np.array_equal(dense_of(row_idx, nvt, n), mask.T)
    # ascending row order within each column's active list
    ri, nv = np.asarray(row_idx), np.asarray(nvt)
    for c in range(n):
        assert np.all(np.diff(ri[c, : nv[c]]) > 0)
    back_idx, back_nv = bcsr_transpose(row_idx, nvt, ncb=n)
    assert np.array_equal(dense_of(back_idx, back_nv, n), mask)


@given(st.integers(0, 10_000), st.integers(1, 12), st.floats(0.05, 0.95))
def test_bcsr_transpose_roundtrip_property(seed, n, density):
    r = np.random.default_rng(seed)
    mask = r.random((n, n)) < density
    np.fill_diagonal(mask, True)
    b = bcsr_from_blockmask(mask, 8)
    row_idx, nvt = bcsr_transpose(b.col_idx, b.nvalid, ncb=n)
    back_idx, back_nv = bcsr_transpose(row_idx, nvt, ncb=n)
    got = np.zeros((n, n), bool)
    bi, bn = np.asarray(back_idx), np.asarray(back_nv)
    for i in range(n):
        got[i, bi[i, : bn[i]]] = True
    assert np.array_equal(got, mask)


def test_bcsr_transpose_jit_and_width_clamp():
    """Runs under jit on traced tables; max_k truncates the padded width."""
    mask = np.zeros((4, 4), bool)
    mask[:, 0] = True          # global-attention stripe: col 0 in every row
    mask[2, 3] = True
    b = bcsr_from_blockmask(mask, 8)
    row_idx, nvt = jax.jit(
        lambda c, n: bcsr_transpose(c, n, ncb=4))(b.col_idx, b.nvalid)
    assert row_idx.shape == (4, 4)
    assert int(nvt[0]) == 4 and np.array_equal(np.asarray(row_idx)[0], [0, 1, 2, 3])
    ri2, nvt2 = bcsr_transpose(b.col_idx, b.nvalid, ncb=4, max_k=2)
    assert ri2.shape == (4, 2) and int(nvt2[0]) == 2


@given(st.integers(0, 10_000), st.integers(1, 12), st.floats(0.05, 0.95))
def test_host_plan_tables_match_under_jit_transpose(seed, n, density):
    """Property: the host-built SparsityPlan transposed tables agree with the
    under-jit bcsr_transpose output (valid prefixes + counts) for random
    block masks, at the plan's true width KT*."""
    r = np.random.default_rng(seed)
    mask = r.random((n, n)) < density
    np.fill_diagonal(mask, True)
    b = bcsr_from_blockmask(mask, 8)
    plan = build_sparsity_plan(b.col_idx, b.nvalid, 8, ncb=n)
    kt = plan.kt_star
    assert kt == int(mask.sum(axis=0).max())          # true column population
    assert plan.tables["row_idx"].shape == (1, n, kt)
    ri_jit, nvt_jit = jax.jit(
        lambda c, v: bcsr_transpose(c, v, ncb=n, max_k=kt))(b.col_idx, b.nvalid)
    ri = np.asarray(plan.tables["row_idx"])[0]
    nvt = np.asarray(plan.tables["nvalid_t"])[0]
    np.testing.assert_array_equal(nvt, np.asarray(nvt_jit))
    ri_jit = np.asarray(ri_jit)
    for c in range(n):
        np.testing.assert_array_equal(ri[c, : nvt[c]], ri_jit[c, : nvt[c]])
    # clamped padding stays in range (the kernels index with it)
    assert ri.min() >= 0 and ri.max() < n


def test_host_transpose_single_layer_and_pinned_width():
    mask = np.zeros((4, 4), bool)
    mask[:, 0] = True
    mask[2, 3] = True
    b = bcsr_from_blockmask(mask, 8)
    ri, nvt, kt = host_transpose_tables(b.col_idx, b.nvalid, ncb=4)
    assert kt == 4 and ri.shape == (4, 4)             # stripe -> population nrb
    np.testing.assert_array_equal(ri[0], [0, 1, 2, 3])
    ri2, nvt2, kt2 = host_transpose_tables(b.col_idx, b.nvalid, ncb=4, max_kt=2)
    assert kt2 == 2 and ri2.shape == (4, 2) and int(nvt2[0]) == 2


@pytest.mark.parametrize("S,hd,block,causal,sw,G", GRAD_SWEEP)
def test_fused_vjp_plan_path_grads_vs_dense_ref(S, hd, block, causal, sw, G, rng):
    """Same contract as test_fused_vjp_grads_vs_dense_ref, but the backward
    consumes the host-built SparsityPlan transposed tables (dK/dV grid width
    KT*) instead of rebuilding them under jit at width nrb."""
    N = 2
    n = S // block
    q = jax.random.normal(jax.random.key(0), (N, G, S, hd))
    k = jax.random.normal(jax.random.key(1), (N, S, hd))
    v = jax.random.normal(jax.random.key(2), (N, S, hd))
    b = _bcsr(rng, n, block)
    col = jnp.maximum(b.col_idx, 0)
    plan = build_sparsity_plan(b.col_idx, b.nvalid, block, ncb=n)
    assert plan.tables["row_idx"].shape[-1] == plan.kt_star <= n
    gout = jax.random.normal(jax.random.key(3), (N, G, S, hd))

    def loss_plan(q, k, v):
        o = fused_block_sparse_attention(
            q, k, v, col, b.nvalid, block=block, causal=causal,
            sliding_window=sw, interpret=True,
            row_idx=plan.tables["row_idx"][0],
            nvalid_t=plan.tables["nvalid_t"][0])
        return jnp.sum(o * gout)

    def loss_ref(q, k, v):
        o = jnp.stack([ref.fused_ref(q[:, g], k, v, b.col_idx, block=block,
                                     causal=causal, sliding_window=sw)
                       for g in range(G)], axis=1)
        return jnp.sum(o * gout)

    got = jax.grad(loss_plan, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for name, a, w in zip("qkv", got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w), atol=1e-3,
                                   err_msg=f"d{name} mismatch (plan path)")


def test_plan_path_grads_equal_fallback_path():
    """Plan-built and under-jit transposed tables must give IDENTICAL dk/dv
    (same accumulation order over ascending row-blocks, fewer grid steps)."""
    S, hd, block = 128, 16, 16
    n = S // block
    rng = np.random.default_rng(5)
    # skewed: sliding-window-ish mask where KT* < nrb
    mask = np.zeros((n, n), bool)
    for r in range(n):
        mask[r, max(r - 1, 0): r + 1] = True
    b = bcsr_from_blockmask(mask, block)
    plan = build_sparsity_plan(b.col_idx, b.nvalid, block, ncb=n)
    assert plan.kt_star < n
    col = jnp.maximum(b.col_idx, 0)
    q = jax.random.normal(jax.random.key(0), (2, 1, S, hd))
    k = jax.random.normal(jax.random.key(1), (2, S, hd))
    v = jax.random.normal(jax.random.key(2), (2, S, hd))

    def loss(q, k, v, use_plan):
        o = fused_block_sparse_attention(
            q, k, v, col, b.nvalid, block=block, causal=True, interpret=True,
            row_idx=plan.tables["row_idx"][0] if use_plan else None,
            nvalid_t=plan.tables["nvalid_t"][0] if use_plan else None)
        return jnp.sum(o ** 2)

    g_plan = jax.grad(lambda *a: loss(*a, True), argnums=(0, 1, 2))(q, k, v)
    g_base = jax.grad(lambda *a: loss(*a, False), argnums=(0, 1, 2))(q, k, v)
    for a, w in zip(g_plan, g_base):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w), atol=1e-6)


def test_default_interpret_resolves_platform():
    # GPU counts as compiled (Triton lane) — only uncompiled backends
    # resolve interpret=None to the interpreter
    expect = jax.default_backend() not in COMPILED_BACKENDS
    assert default_interpret(None) is expect
    assert default_interpret(True) is True
    assert default_interpret(False) is False


@pytest.mark.parametrize("arch", ["spion-lra", "qwen2-7b", "mixtral-8x7b"])
@pytest.mark.parametrize("config", [None, KernelConfig(depth=1)],
                         ids=["default", "depth1"])
def test_kernel_wrapper_vs_bcsr_attention(arch, config, rng):
    """The fused kernel is the only spion_attention_kernel path; a tuned
    KernelConfig rides through the wrapper without changing results."""
    cfg = get_config(arch)
    if cfg.sliding_window:
        cfg = cfg.replace(sliding_window=96)
    B, S, H, KV, hd, blk = 2, 256, 4, 2, 32, 64
    q = jax.random.normal(jax.random.key(1), (B, S, H, hd))
    k = jax.random.normal(jax.random.key(2), (B, S, KV, hd))
    v = jax.random.normal(jax.random.key(3), (B, S, KV, hd))
    b = _bcsr(rng, S // blk, blk)
    want = bcsr_attention(cfg, q, k, v, b)
    out = spion_attention_kernel(cfg, q, k, v, b, interpret=True,
                                 config=config)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
