"""Pallas kernel validation (interpret mode): shape/dtype sweeps vs the
ref.py pure-jnp oracles and vs the BCSR jnp path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.sparse_attention import bcsr_attention, bcsr_from_blockmask
from repro.kernels import ref
from repro.kernels.block_sparse_attn import fused_block_sparse_attention
from repro.kernels.ops import spion_attention_kernel
from repro.kernels.sddmm import sddmm
from repro.kernels.sparse_softmax import sparse_softmax
from repro.kernels.spmm import spmm


def _tables(rng, n, K_density=0.5):
    mask = rng.random((n, n)) < K_density
    np.fill_diagonal(mask, True)
    b = bcsr_from_blockmask(mask, 0 or 1, None)  # placeholder
    return mask


def _bcsr(rng, n, block, density=0.5):
    mask = rng.random((n, n)) < density
    np.fill_diagonal(mask, True)
    return bcsr_from_blockmask(mask, block)


SWEEP = [
    # (S, hd, block, dtype, causal, sw)
    (128, 32, 32, jnp.float32, False, None),
    (128, 32, 32, jnp.float32, True, None),
    (256, 64, 64, jnp.float32, True, 96),
    (128, 16, 32, jnp.bfloat16, True, None),
    (64, 128, 32, jnp.float32, False, None),
]


@pytest.mark.parametrize("S,hd,block,dtype,causal,sw", SWEEP)
def test_sddmm_vs_ref(S, hd, block, dtype, causal, sw, rng):
    N = 2
    q = jax.random.normal(jax.random.key(0), (N, S, hd), dtype)
    k = jax.random.normal(jax.random.key(1), (N, S, hd), dtype)
    b = _bcsr(rng, S // block, block)
    col = jnp.maximum(b.col_idx, 0)
    out = sddmm(q, k, col, b.nvalid, block=block, causal=causal,
                sliding_window=sw, interpret=True)
    want = ref.sddmm_ref(q, k, b.col_idx, block=block, causal=causal,
                         sliding_window=sw)
    # compare only at unmasked positions (both use -inf at masked)
    fin = np.isfinite(np.asarray(want))
    np.testing.assert_allclose(np.asarray(out)[fin], np.asarray(want)[fin],
                               atol=5e-2 if dtype == jnp.bfloat16 else 2e-5)
    assert np.all(np.isneginf(np.asarray(out)[~fin]))


@pytest.mark.parametrize("S,hd,block,dtype,causal,sw", SWEEP)
def test_softmax_spmm_vs_ref(S, hd, block, dtype, causal, sw, rng):
    N = 2
    q = jax.random.normal(jax.random.key(0), (N, S, hd), dtype)
    k = jax.random.normal(jax.random.key(1), (N, S, hd), dtype)
    v = jax.random.normal(jax.random.key(2), (N, S, hd), dtype)
    b = _bcsr(rng, S // block, block)
    col = jnp.maximum(b.col_idx, 0)
    s = ref.sddmm_ref(q, k, b.col_idx, block=block, causal=causal, sliding_window=sw)
    p = sparse_softmax(s, col, b.nvalid, block=block, seq_len=S, causal=causal,
                       sliding_window=sw, interpret=True)
    p_ref = ref.sparse_softmax_ref(s, b.col_idx, block=block, seq_len=S,
                                   causal=causal, sliding_window=sw)
    np.testing.assert_allclose(np.asarray(p), np.asarray(p_ref), atol=2e-6)
    o = spmm(p, v, col, b.nvalid, block=block, interpret=True)
    o_ref = ref.spmm_ref(p_ref, v, b.col_idx)
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(o_ref, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 2e-5)


@pytest.mark.parametrize("S,hd,block,dtype,causal,sw", SWEEP)
def test_fused_kernel_vs_ref(S, hd, block, dtype, causal, sw, rng):
    N, G = 2, 2
    q = jax.random.normal(jax.random.key(0), (N, G, S, hd), dtype)
    k = jax.random.normal(jax.random.key(1), (N, S, hd), dtype)
    v = jax.random.normal(jax.random.key(2), (N, S, hd), dtype)
    b = _bcsr(rng, S // block, block)
    col = jnp.maximum(b.col_idx, 0)
    out = fused_block_sparse_attention(q, k, v, col, b.nvalid, block=block,
                                       causal=causal, sliding_window=sw,
                                       interpret=True)
    want = jnp.stack([
        ref.fused_ref(q[:, g], k, v, b.col_idx, block=block, causal=causal,
                      sliding_window=sw) for g in range(G)], axis=1)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=6e-2 if dtype == jnp.bfloat16 else 3e-5)


@pytest.mark.parametrize("arch", ["spion-lra", "qwen2-7b", "mixtral-8x7b"])
@pytest.mark.parametrize("fused", [True, False])
def test_kernel_wrapper_vs_bcsr_attention(arch, fused, rng):
    cfg = get_config(arch)
    if cfg.sliding_window:
        cfg = cfg.replace(sliding_window=96)
    B, S, H, KV, hd, blk = 2, 256, 4, 2, 32, 64
    q = jax.random.normal(jax.random.key(1), (B, S, H, hd))
    k = jax.random.normal(jax.random.key(2), (B, S, KV, hd))
    v = jax.random.normal(jax.random.key(3), (B, S, KV, hd))
    b = _bcsr(rng, S // blk, blk)
    want = bcsr_attention(cfg, q, k, v, b)
    out = spion_attention_kernel(cfg, q, k, v, b, fused=fused, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
