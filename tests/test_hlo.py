"""HLO collective-bytes parser + roofline term arithmetic."""
import pytest

from repro.launch.hlo import collective_stats, op_census, roofline_terms

SAMPLE = """
HloModule jit_step
fused_computation {
  p0 = bf16[128,256]{1,0} parameter(0)
}
ENTRY main {
  %x = bf16[128,256]{1,0} parameter(0)
  %y = f32[64]{0} parameter(1)
  %ar = bf16[128,256]{1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[512,256]{1,0} all-gather(%x), dimensions={0}
  %rs = f32[16]{0} reduce-scatter(%y), dimensions={0}
  %cp = bf16[128,256]{1,0} collective-permute(%x), source_target_pairs={{0,1}}
  %a2a = bf16[128,256]{1,0} all-to-all(%x), dimensions={0}
  ROOT %out = bf16[128,256]{1,0} add(%ar, %cp)
}
"""


def test_collective_stats_operand_sizes():
    s = collective_stats(SAMPLE)
    x_bytes = 128 * 256 * 2
    y_bytes = 64 * 4
    assert s["by_kind"]["all-reduce"] == x_bytes
    assert s["by_kind"]["all-gather"] == x_bytes       # operand, not result
    assert s["by_kind"]["reduce-scatter"] == y_bytes
    assert s["by_kind"]["collective-permute"] == x_bytes
    assert s["by_kind"]["all-to-all"] == x_bytes
    assert s["total_bytes"] == 4 * x_bytes + y_bytes
    assert s["count"]["all-reduce"] == 1


def test_collective_stats_async_start_done_not_double_counted():
    txt = """
ENTRY main {
  %x = bf16[8,8]{1,0} parameter(0)
  %s = bf16[8,8]{1,0} all-gather-start(%x), dimensions={0}
  %d = bf16[8,8]{1,0} all-gather-done(%s)
}
"""
    s = collective_stats(txt)
    assert s["count"]["all-gather"] == 1
    assert s["by_kind"]["all-gather"] == 8 * 8 * 2


def test_op_census():
    c = op_census(SAMPLE)
    assert c["all-reduce"] == 1 and c["all-gather"] == 1


def test_roofline_terms():
    t = roofline_terms(197e12, 819e9, 50e9, 1, peak_flops=197e12,
                       hbm_bw=819e9, link_bw=50e9)
    assert t["t_compute"] == pytest.approx(1.0)
    assert t["t_memory"] == pytest.approx(1.0)
    assert t["t_collective"] == pytest.approx(1.0)
