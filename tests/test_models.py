"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step on CPU, asserting shapes and no NaNs; decode parity checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import make_train_step
from repro.models.registry import build
from repro.optim import adamw_init


def _batch(cfg, key, B=2, S=64):
    ks = jax.random.split(key, 3)
    if cfg.family in ("audio", "encdec"):
        return {
            "frames": jax.random.normal(ks[0], (B, S, cfg.d_model), jnp.float32),
            "tokens": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
            "labels": jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size),
        }
    if cfg.family == "vlm":
        St = S - cfg.num_patch_tokens
        return {
            "tokens": jax.random.randint(ks[1], (B, St), 0, cfg.vocab_size),
            "patch_embeds": jax.random.normal(ks[0], (B, cfg.num_patch_tokens, cfg.d_model)),
            "labels": jax.random.randint(ks[2], (B, St), 0, cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_config(arch).reduced()
    b = build(cfg)
    params = b.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    logits, _ = jax.jit(lambda p, x: b.forward(p, x))(params, batch)
    B = batch["tokens"].shape[0]
    S_expect = batch["tokens"].shape[1] + (cfg.num_patch_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_expect, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    b = build(cfg)
    params = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.ndim >= 2 else x,
        b.init(jax.random.key(0)))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg))
    batch = _batch(cfg, jax.random.key(1))
    params2, opt2, metrics = step(params, opt, batch, jnp.int32(0))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["gnorm"]))
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l.astype(jnp.float32)))),
        jax.tree_util.tree_map(lambda a, c: a - c, params2, params), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ["qwen2-7b", "command-r-35b", "whisper-tiny",
                                  "rwkv6-7b", "zamba2-1.2b", "mixtral-8x7b"])
def test_decode_matches_forward(arch, monkeypatch):
    """Teacher-forced decode over a short sequence reproduces the forward
    logits (KV-cache / recurrent-state correctness, incl. chunked-vs-
    recurrent parity for the SSM families)."""
    cfg = get_config(arch).reduced().replace(remat=False)
    if cfg.ssm is not None:
        cfg = cfg.replace(ssm=cfg.ssm.__class__(
            state_size=cfg.ssm.state_size, head_dim=cfg.ssm.head_dim,
            expand=cfg.ssm.expand, chunk=4))
    if cfg.moe is not None:
        # decode parity needs dropless routing on both paths
        import repro.models.moe as moe_mod
        monkeypatch.setattr(moe_mod, "CAPACITY_FACTOR", 16.0)
    b = build(cfg)
    params = b.init(jax.random.key(0))
    B, S = 2, 16
    batch = _batch(cfg, jax.random.key(1), B=B, S=S)
    logits_fwd, _ = b.forward(params, batch)
    cache = b.init_cache(B, S)
    if cfg.family in ("audio", "encdec"):
        from repro.models.encdec import precompute_cross
        ck, cv = precompute_cross(params, cfg, batch["frames"])
        cache["ck"], cache["cv"] = ck, cv
    errs = []
    decode = jax.jit(b.decode_step)
    for t in range(S):
        tok = batch["tokens"][:, t:t + 1]
        lg, cache = decode(params, cache, tok, jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(
            lg.astype(jnp.float32) - logits_fwd[:, t].astype(jnp.float32)))))
    assert max(errs) < 5e-2, f"decode/forward divergence: {errs}"


def test_moe_router_load_balance_loss_positive():
    cfg = get_config("mixtral-8x7b").reduced()
    b = build(cfg)
    params = b.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    _, aux = b.forward(params, batch)
    assert float(aux["lb_loss"]) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz
    assert float(aux["z_loss"]) >= 0.0


def test_vlm_loss_masks_patch_positions():
    cfg = get_config("internvl2-2b").reduced()
    b = build(cfg)
    params = b.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    loss, _ = b.loss(params, batch)
    assert np.isfinite(float(loss))


def test_fp8_kv_cache_decode_close_to_bf16():
    """cache_dtype=float8_e4m3fn (hillclimb A3) must stay close to the
    full-precision decode distribution."""
    import jax
    import jax.numpy as jnp
    cfg = get_config("qwen2-7b").reduced().replace(remat=False)
    b = build(cfg)
    params = b.init(jax.random.key(0))
    cfg8 = cfg.replace(cache_dtype="float8_e4m3fn")
    b8 = build(cfg8)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    c1, c2 = b.init_cache(B, S), b8.init_cache(B, S)
    assert c2["k"].dtype == jnp.float8_e4m3fn
    for t in range(S):
        l1, c1 = b.decode_step(params, c1, toks[:, t:t+1], jnp.int32(t))
        l2, c2 = b8.decode_step(params, c2, toks[:, t:t+1], jnp.int32(t))
    p1 = jax.nn.softmax(l1.astype(jnp.float32), -1)
    p2 = jax.nn.softmax(l2.astype(jnp.float32), -1)
    tv = float(0.5 * jnp.abs(p1 - p2).sum(-1).max())
    assert tv < 0.15, f"fp8 cache drifted too far: TV={tv}"
