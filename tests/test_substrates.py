"""Optimizer, schedules, quantisation, checkpointing, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.checkpoint import CheckpointManager
from repro.distributed.fault import Heartbeat, StepSupervisor, StragglerMonitor
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.grad import dequantize_int8, quantize_int8
from repro.optim.schedule import cosine_schedule, linear_warmup


# -- optimizer ----------------------------------------------------------------

def test_adamw_first_step_is_scaled_sign():
    params = {"w": jnp.ones((3, 3))}
    grads = {"w": jnp.full((3, 3), 0.5)}
    st_ = adamw_init(params)
    p2, st2 = adamw_update(params, grads, st_, lr=0.1, weight_decay=0.0)
    # first Adam step with bias correction = lr * g/|g| (per element)
    np.testing.assert_allclose(np.asarray(p2["w"]), 1.0 - 0.1, atol=1e-4)
    assert int(st2["count"]) == 1


def test_adamw_no_decay_on_1d():
    params = {"scale": jnp.ones((4,)), "w": jnp.ones((4, 4))}
    grads = jax.tree_util.tree_map(jnp.zeros_like, params)
    p2, _ = adamw_update(params, grads, adamw_init(params), lr=0.1,
                         weight_decay=0.5)
    np.testing.assert_allclose(np.asarray(p2["scale"]), 1.0)       # no decay
    assert float(p2["w"][0, 0]) < 1.0                              # decayed


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0)
    _, norm2 = clip_by_global_norm(clipped, 1e9)
    assert float(norm2) == pytest.approx(1.0, rel=1e-4)


def test_schedules():
    assert float(linear_warmup(0, peak=1.0, warmup_steps=10)) == pytest.approx(0.1)
    assert float(cosine_schedule(0, peak=1.0, warmup_steps=10, total_steps=100)) < 0.2
    assert float(cosine_schedule(100, peak=1.0, warmup_steps=10, total_steps=100)) \
        == pytest.approx(0.1, abs=1e-3)


@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1, max_size=64))
def test_quantize_roundtrip_error_bound(xs):
    x = jnp.asarray(np.array(xs, np.float32))
    q, s = quantize_int8(x)
    err = np.max(np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x)))
    amax = float(np.max(np.abs(np.asarray(x))))
    assert err <= amax / 127.0 * 0.5 + 1e-6


# -- checkpointing -------------------------------------------------------------

def _tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": {"count": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = _tree()
    mgr.save(10, tree, extra={"phase": "sparse"})
    got, step, extra = mgr.restore(target=tree)
    assert step == 10 and extra["phase"] == "sparse"
    np.testing.assert_allclose(np.asarray(got["params"]["w"]),
                               np.asarray(tree["params"]["w"]))


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_ignores_uncommitted(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, _tree())
    # a torn checkpoint without DONE marker must be invisible
    os.makedirs(tmp_path / "step_000000099")
    assert mgr.latest_step() == 1


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    mgr.save(5, _tree())
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_restore_waits_for_async_save(tmp_path):
    """restore()/latest_step() immediately after an async save() must see
    the step being committed, not a half-written (or absent) directory."""
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    tree = _tree()
    mgr.save(5, tree)
    assert mgr.latest_step() == 5          # no explicit wait() in between
    mgr.save(6, tree)
    got, step, _ = mgr.restore(target=tree)
    assert step == 6
    np.testing.assert_allclose(np.asarray(got["params"]["w"]),
                               np.asarray(tree["params"]["w"]))


def test_checkpoint_async_write_failure_surfaces(tmp_path, monkeypatch):
    """A failed background write must raise on the NEXT save()/wait(), not
    die silently in the daemon thread (training would keep going with no
    durable checkpoints)."""
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)

    def boom(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(mgr, "_write", boom)
    mgr.save(1, _tree())
    with pytest.raises(RuntimeError, match="background write failed"):
        mgr.wait()
    # the error is consumed once surfaced; the manager stays usable
    monkeypatch.undo()
    mgr.save(2, _tree())
    mgr.wait()
    assert mgr.latest_step() == 2

    monkeypatch.setattr(mgr, "_write", boom)
    mgr.save(3, _tree())
    with pytest.raises(RuntimeError, match="background write failed"):
        mgr.save(4, _tree())  # surfacing via save()'s leading wait()


def test_checkpoint_crash_mid_save_recovery(tmp_path):
    """A save that died after writing arrays.npz but before the DONE+rename
    commit: latest_step falls back to the previous committed step, and the
    orphaned tmp dir is reaped by the next save instead of leaking a full
    checkpoint of disk per crash."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    tree = _tree()
    mgr.save(1, tree)
    # simulate the crash: tmp dir with real payload, no DONE, no rename
    orphan = tmp_path / ".tmp_step_000000002"
    os.makedirs(orphan)
    np.savez(orphan / "arrays.npz", leaf_0=np.zeros(3))
    assert mgr.latest_step() == 1
    got, step, _ = mgr.restore(target=tree)
    assert step == 1
    mgr.save(3, tree)
    assert not orphan.exists()
    assert mgr.all_steps() == [1, 3]


def test_chaos_monkey_env_arming(monkeypatch):
    from repro.distributed.chaos import ChaosMonkey
    assert ChaosMonkey.from_env() is None  # unarmed by default
    monkeypatch.setenv("SPION_CHAOS_KILL_STEP", "11")
    monkeypatch.setenv("SPION_CHAOS_SIGNAL", "TERM")
    cm = ChaosMonkey.from_env()
    assert cm.kill_step == 11 and cm.sig == "TERM" and cm.kill_process is None
    assert not cm.armed_for(10)
    assert cm.armed_for(11) and cm.armed_for(12)
    cm.fired = True
    assert not cm.armed_for(12)  # one shot
    with pytest.raises(ValueError):
        ChaosMonkey(sig="SEGV")


# -- fault tolerance ------------------------------------------------------------

def test_supervisor_restores_and_retries():
    calls = {"restore": 0, "step": 0}

    def restore():
        calls["restore"] += 1

    sup = StepSupervisor(restore, max_retries=3, sleep_fn=lambda d: None)

    def flaky():
        calls["step"] += 1
        if calls["step"] < 3:
            raise RuntimeError("simulated device failure")
        return "ok"

    assert sup.run(flaky) == "ok"
    assert calls["restore"] == 2
    assert sup.restarts == 2


def test_supervisor_gives_up():
    sup = StepSupervisor(lambda: None, max_retries=1, sleep_fn=lambda d: None)
    with pytest.raises(RuntimeError):
        sup.run(lambda: (_ for _ in ()).throw(RuntimeError("boom")))


def test_supervisor_backoff_schedule():
    """Capped exponential with bounded multiplicative jitter, one sleep per
    retry (none after the final failing attempt)."""
    import random
    slept = []
    sup = StepSupervisor(lambda: None, max_retries=4, backoff_base=0.5,
                         backoff_max=2.0, jitter=0.25,
                         sleep_fn=slept.append, rng=random.Random(0))
    with pytest.raises(RuntimeError):
        sup.run(lambda: (_ for _ in ()).throw(RuntimeError("boom")))
    assert len(slept) == 4  # 5 attempts -> 4 backoffs between them
    for i, d in enumerate(slept):
        lo = min(0.5 * 2.0 ** i, 2.0)
        assert lo <= d < lo * 1.25, (i, d)
    assert slept[2] >= 2.0 and slept[3] < 2.0 * 1.25  # cap engaged


def test_supervisor_retries_connection_error():
    """ConnectionError is an OSError subclass — dropping it from RETRYABLE
    must not change behaviour."""
    assert ConnectionError not in StepSupervisor.RETRYABLE
    sup = StepSupervisor(lambda: None, max_retries=2, sleep_fn=lambda d: None)
    n = {"v": 0}

    def step():
        n["v"] += 1
        if n["v"] == 1:
            raise ConnectionError("coordinator hiccup")
        return "ok"

    assert sup.run(step) == "ok"


def test_supervisor_no_retry_on_programming_error():
    sup = StepSupervisor(lambda: None, max_retries=3, sleep_fn=lambda d: None)
    with pytest.raises(ValueError):
        sup.run(lambda: (_ for _ in ()).throw(ValueError("bad shape")))
    assert sup.restarts == 0


def test_flaky_wrapper_with_supervisor():
    from repro.distributed.chaos import flaky
    sup = StepSupervisor(lambda: None, max_retries=3, sleep_fn=lambda d: None)
    step = flaky(lambda x: x * 2, fail_on_calls=(1, 2))
    assert sup.run(step, 21) == 42
    assert step.calls["n"] == 3
    assert sup.restarts == 2


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(warmup=5, z=3.0)
    flagged = [mon.observe(1.0 + 0.01 * i) for i in range(20)]
    assert not any(flagged)
    assert mon.observe(10.0) is True
    assert mon.observe(1.0) is False  # stats not poisoned


def test_heartbeat_dead_host_detection(tmp_path):
    p1, p2 = str(tmp_path / "h1"), str(tmp_path / "h2")
    Heartbeat(p1, interval=0).beat(now=1000.0)
    Heartbeat(p2, interval=0).beat(now=2000.0)
    dead = Heartbeat.dead_hosts([p1, p2], timeout=500, now=2100.0)
    assert dead == [p1]


def test_heartbeat_zero_timestamp(tmp_path):
    """now=0.0 is a legitimate clock value (monotonic-from-zero test clocks);
    the old `now or time.time()` treated it as "not provided" and substituted
    wall time — beat() wrote an epoch-now timestamp and dead_hosts() compared
    against the wrong now."""
    p = str(tmp_path / "h")
    hb = Heartbeat(p, interval=0.0)
    hb.beat(now=0.0)
    assert Heartbeat.read(p)["ts"] == 0.0
    # a host last seen at t=0 evaluated at now=0 is alive, not 50-years dead
    assert Heartbeat.dead_hosts([p], timeout=5.0, now=0.0) == []
    assert Heartbeat.dead_hosts([p], timeout=5.0, now=6.0) == [p]


def test_heartbeat_json_payload_and_legacy(tmp_path):
    """beat() writes a JSON payload {ts, pid, step, phase, ...}; read()
    parses it and still accepts the pre-JSON bare-timestamp format, so a
    supervisor scanning a mixed-version fleet sees every host."""
    p = str(tmp_path / "h")
    hb = Heartbeat(p, interval=0.0)
    hb.beat(now=100.0, step=7, phase="sparse", extra={"stragglers": 2})
    got = Heartbeat.read(p)
    assert got["ts"] == 100.0 and got["step"] == 7
    assert got["phase"] == "sparse" and got["stragglers"] == 2
    assert got["pid"] == os.getpid()
    # payload fields persist across beats that don't re-supply them
    hb.beat(now=200.0)
    assert Heartbeat.read(p)["step"] == 7
    # legacy format: a bare float timestamp
    legacy = str(tmp_path / "old")
    with open(legacy, "w") as f:
        f.write("1234.5")
    assert Heartbeat.read(legacy) == {"ts": 1234.5}
    assert Heartbeat.dead_hosts([legacy], timeout=10.0, now=1240.0) == []
    assert Heartbeat.dead_hosts([legacy], timeout=1.0, now=1240.0) == [legacy]
    # missing / unparseable files read as None and count as dead
    assert Heartbeat.read(str(tmp_path / "missing")) is None
    garbled = str(tmp_path / "bad")
    with open(garbled, "w") as f:
        f.write("{not json")
    assert Heartbeat.read(garbled) is None
    assert Heartbeat.dead_hosts([garbled], timeout=10.0, now=20.0) == [garbled]


def test_heartbeat_thread_keeps_ts_fresh(tmp_path):
    """The daemon beat thread refreshes ts while the 'main thread' (this
    test) never calls beat() — the property that makes a hung step
    detectable as fresh-ts/frozen-step rather than dead."""
    import time as _time
    p = str(tmp_path / "h")
    hb = Heartbeat(p, interval=0.05)
    hb.beat(step=3)
    hb.start_thread()
    try:
        deadline = _time.time() + 5.0
        first = Heartbeat.read(p)["ts"]
        while _time.time() < deadline:
            got = Heartbeat.read(p)
            if got["ts"] > first:
                assert got["step"] == 3  # payload rides every pulse
                break
            _time.sleep(0.02)
        else:
            raise AssertionError("beat thread never refreshed ts")
    finally:
        hb.stop_thread()


# -- checkpoint pinning / quarantine (divergence rollback support) -------------

def test_checkpoint_gc_never_removes_pinned_step(tmp_path):
    """A pinned step (the rollback target) survives however far training
    runs past the keep window; unpinning re-exposes it to the next GC."""
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    mgr.save(1, _tree())
    mgr.pin(1)
    for s in (2, 3, 4, 5):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [1, 4, 5]  # 1 outlives keep=2 only via the pin
    assert mgr.pinned() == [1]
    mgr.unpin(1)
    mgr.save(6, _tree())  # next GC reclaims the unpinned step
    assert mgr.all_steps() == [5, 6]


def test_checkpoint_reap_orphans_skips_pinned(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, _tree())
    pinned = tmp_path / ".tmp_step_000000007"
    stray = tmp_path / ".tmp_step_000000008"
    os.makedirs(pinned)
    os.makedirs(stray)
    mgr.pin(7)
    mgr.save(2, _tree())  # save path runs _reap_orphans
    assert pinned.exists() and not stray.exists()


def test_checkpoint_quarantine_after(tmp_path):
    """quarantine_after(g) hides every committed step > g from restore /
    latest_step (poisoned post-divergence saves must never be resumed
    from) while keeping the payload on disk for forensics."""
    mgr = CheckpointManager(str(tmp_path), keep=0, async_save=False)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    mgr.quarantine_after(2)
    assert mgr.all_steps() == [1, 2]
    assert mgr.latest_step() == 2
    got, step, _ = mgr.restore(target=tree)
    assert step == 2
    assert (tmp_path / "quarantined_step_000000003").exists()
    assert (tmp_path / "quarantined_step_000000004").exists()
    # idempotent: re-quarantining (e.g. a second rollback to the same good
    # step after more saves) must not trip over existing quarantine dirs
    mgr.save(5, tree)
    mgr.quarantine_after(2)
    assert mgr.all_steps() == [1, 2]


# -- chaos: hang + NaN-poison arms --------------------------------------------

def test_chaos_hang_and_nan_arming(monkeypatch):
    from repro.distributed.chaos import ChaosMonkey
    monkeypatch.setenv("SPION_CHAOS_HANG_STEP", "12")
    monkeypatch.setenv("SPION_CHAOS_HANG_SECONDS", "7.5")
    monkeypatch.setenv("SPION_CHAOS_NAN_STEP", "13")
    cm = ChaosMonkey.from_env()
    assert cm.hang_step == 12 and cm.hang_seconds == 7.5 and cm.nan_step == 13
    slept = []
    cm.maybe_hang(11, sleep_fn=slept.append)
    assert slept == []
    cm.maybe_hang(12, sleep_fn=slept.append)
    assert slept == [7.5]
    cm.maybe_hang(12, sleep_fn=slept.append)
    assert slept == [7.5]  # one shot
    assert not cm.poison_due(12)
    assert cm.poison_due(13)
    assert not cm.poison_due(14)  # one shot


def test_chaos_once_markers_survive_respawn(tmp_path, monkeypatch):
    """once_dir markers make each injection at-most-once across process
    incarnations: a supervisor-respawned fleet replaying through the armed
    step must NOT re-trigger the fault (that would crash-loop forever)."""
    from repro.distributed.chaos import ChaosMonkey
    once = str(tmp_path / "once")

    def fresh():
        return ChaosMonkey(hang_step=5, nan_step=6, kill_step=7,
                           once_dir=once)

    cm = fresh()
    slept = []
    cm.maybe_hang(5, sleep_fn=slept.append)
    assert slept and os.path.exists(os.path.join(once, "chaos_fired_hang"))
    assert cm.poison_due(6)
    assert cm.armed_for(7)
    cm._mark("kill")  # maybe_kill would SIGKILL us; mark like it does
    # "respawned" incarnation: fresh in-memory state, same once_dir
    cm2 = fresh()
    slept2 = []
    cm2.maybe_hang(5, sleep_fn=slept2.append)
    assert slept2 == []
    assert not cm2.poison_due(6)
    assert not cm2.armed_for(7)


# -- divergence sentinel -------------------------------------------------------

def test_sentinel_flags_nonfinite():
    from repro.distributed.fault import DivergenceSentinel
    s = DivergenceSentinel(spike=False)
    assert not s.observe(2.0)
    assert s.observe(float("nan"))
    assert s.observe(float("inf"))
    assert s.observe(float("-inf"))
    assert not s.observe(3.0)  # recovers: verdicts are per-observation


def test_sentinel_flags_loss_spike_and_resets():
    from repro.distributed.fault import DivergenceSentinel
    s = DivergenceSentinel(z=6.0, warmup=5)
    for i in range(20):
        assert not s.observe(4.0 - 0.01 * i)  # healthy decreasing loss
    assert s.observe(400.0)  # explosion
    s.reset()
    for _ in range(5):
        assert not s.observe(400.0)  # post-rollback warmup: new baseline
