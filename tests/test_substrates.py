"""Optimizer, schedules, quantisation, checkpointing, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.checkpoint import CheckpointManager
from repro.distributed.fault import Heartbeat, StepSupervisor, StragglerMonitor
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.grad import dequantize_int8, quantize_int8
from repro.optim.schedule import cosine_schedule, linear_warmup


# -- optimizer ----------------------------------------------------------------

def test_adamw_first_step_is_scaled_sign():
    params = {"w": jnp.ones((3, 3))}
    grads = {"w": jnp.full((3, 3), 0.5)}
    st_ = adamw_init(params)
    p2, st2 = adamw_update(params, grads, st_, lr=0.1, weight_decay=0.0)
    # first Adam step with bias correction = lr * g/|g| (per element)
    np.testing.assert_allclose(np.asarray(p2["w"]), 1.0 - 0.1, atol=1e-4)
    assert int(st2["count"]) == 1


def test_adamw_no_decay_on_1d():
    params = {"scale": jnp.ones((4,)), "w": jnp.ones((4, 4))}
    grads = jax.tree_util.tree_map(jnp.zeros_like, params)
    p2, _ = adamw_update(params, grads, adamw_init(params), lr=0.1,
                         weight_decay=0.5)
    np.testing.assert_allclose(np.asarray(p2["scale"]), 1.0)       # no decay
    assert float(p2["w"][0, 0]) < 1.0                              # decayed


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(10.0)
    _, norm2 = clip_by_global_norm(clipped, 1e9)
    assert float(norm2) == pytest.approx(1.0, rel=1e-4)


def test_schedules():
    assert float(linear_warmup(0, peak=1.0, warmup_steps=10)) == pytest.approx(0.1)
    assert float(cosine_schedule(0, peak=1.0, warmup_steps=10, total_steps=100)) < 0.2
    assert float(cosine_schedule(100, peak=1.0, warmup_steps=10, total_steps=100)) \
        == pytest.approx(0.1, abs=1e-3)


@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1, max_size=64))
def test_quantize_roundtrip_error_bound(xs):
    x = jnp.asarray(np.array(xs, np.float32))
    q, s = quantize_int8(x)
    err = np.max(np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x)))
    amax = float(np.max(np.abs(np.asarray(x))))
    assert err <= amax / 127.0 * 0.5 + 1e-6


# -- checkpointing -------------------------------------------------------------

def _tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": {"count": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = _tree()
    mgr.save(10, tree, extra={"phase": "sparse"})
    got, step, extra = mgr.restore(target=tree)
    assert step == 10 and extra["phase"] == "sparse"
    np.testing.assert_allclose(np.asarray(got["params"]["w"]),
                               np.asarray(tree["params"]["w"]))


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_ignores_uncommitted(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, _tree())
    # a torn checkpoint without DONE marker must be invisible
    os.makedirs(tmp_path / "step_000000099")
    assert mgr.latest_step() == 1


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    mgr.save(5, _tree())
    mgr.wait()
    assert mgr.latest_step() == 5


# -- fault tolerance ------------------------------------------------------------

def test_supervisor_restores_and_retries():
    calls = {"restore": 0, "step": 0}

    def restore():
        calls["restore"] += 1

    sup = StepSupervisor(restore, max_retries=3)

    def flaky():
        calls["step"] += 1
        if calls["step"] < 3:
            raise RuntimeError("simulated device failure")
        return "ok"

    assert sup.run(flaky) == "ok"
    assert calls["restore"] == 2
    assert sup.restarts == 2


def test_supervisor_gives_up():
    sup = StepSupervisor(lambda: None, max_retries=1)
    with pytest.raises(RuntimeError):
        sup.run(lambda: (_ for _ in ()).throw(RuntimeError("boom")))


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(warmup=5, z=3.0)
    flagged = [mon.observe(1.0 + 0.01 * i) for i in range(20)]
    assert not any(flagged)
    assert mon.observe(10.0) is True
    assert mon.observe(1.0) is False  # stats not poisoned


def test_heartbeat_dead_host_detection(tmp_path):
    p1, p2 = str(tmp_path / "h1"), str(tmp_path / "h2")
    Heartbeat(p1, interval=0).beat(now=1000.0)
    Heartbeat(p2, interval=0).beat(now=2000.0)
    dead = Heartbeat.dead_hosts([p1, p2], timeout=500, now=2100.0)
    assert dead == [p1]
