"""Data pipeline: ListOps generator correctness + batching + prefetch."""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.data.listops import (CLS, DIG0, OP0, OPEN, CLOSE, PAD, VOCAB_SIZE,
                                _eval, _sample_tree, generate_listops,
                                make_listops_batch)
from repro.data.pipeline import ShardedBatcher
from repro.data.synthetic import lm_batch_iterator, synthetic_task_batch


@given(st.integers(0, 10_000))
def test_listops_eval_oracle(seed):
    """_eval agrees with a brute-force interpreter."""
    rng = np.random.default_rng(seed)
    tree = _sample_tree(rng, 4, 4)

    def brute(node):
        if isinstance(node, int):
            return node
        op, args = node
        vals = [brute(a) for a in args]
        return {"MIN": min, "MAX": max,
                "MED": lambda v: int(np.median(v)),
                "SM": lambda v: sum(v) % 10}[op](vals)
    assert _eval(tree) == brute(tree)
    assert 0 <= _eval(tree) <= 9


def test_listops_tokens_wellformed():
    rng = np.random.default_rng(0)
    toks, label = generate_listops(rng, 128)
    assert toks.shape == (128,)
    assert toks[0] == CLS
    assert 0 <= label <= 9
    assert toks.max() < VOCAB_SIZE
    body = toks[toks != PAD]
    assert (body == OPEN).sum() == (body == CLOSE).sum()  # balanced


def test_listops_batch():
    rng = np.random.default_rng(1)
    xs, ys = make_listops_batch(rng, 4, 64, depth=3)
    assert xs.shape == (4, 64) and ys.shape == (4,)


def test_lm_iterator_shapes():
    rng = np.random.default_rng(0)
    it = lm_batch_iterator(rng, batch=2, seq_len=17, vocab=100)
    b = next(it)
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_synthetic_tasks():
    rng = np.random.default_rng(0)
    x, y = synthetic_task_batch(rng, "image", batch=4, seq_len=256)
    assert x.shape == (4, 256) and y.max() < 10
    x, y = synthetic_task_batch(rng, "retrieval", batch=4, seq_len=256)
    assert set(np.unique(y)).issubset({0, 1})


def test_sharded_batcher_prefetch():
    def gen():
        for i in range(5):
            yield {"x": np.full((2, 2), i)}
    out = list(ShardedBatcher(gen(), mesh=None, depth=2))
    assert len(out) == 5
    assert float(out[3]["x"][0, 0]) == 3


def test_sharded_batcher_propagates_errors():
    def gen():
        yield {"x": np.zeros((1,))}
        raise ValueError("source died")
    it = ShardedBatcher(gen(), mesh=None)
    next(it)
    with pytest.raises(ValueError):
        next(it)
        next(it)
