"""BCSR sparse attention vs dense / masked-softmax oracles (paper Eq. 5 +
Alg. 6 zero-correction semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.sparse_attention import (bcsr_attention, bcsr_attention_ops,
                                         bcsr_from_blockmask, full_bcsr)
from repro.models.attention import dense_attention


def _qkv(key, B, S, H, KV, hd):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (B, S, H, hd)),
            jax.random.normal(ks[1], (B, S, KV, hd)),
            jax.random.normal(ks[2], (B, S, KV, hd)))


def _oracle(cfg, q, k, v, blockmask, block):
    """Dense masked-softmax with the paper's zero-correction."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) / np.sqrt(hd)
    allow = jnp.asarray(np.repeat(np.repeat(blockmask, block, 0), block, 1))
    total = jnp.ones((S, S), bool)
    if cfg.causal:
        total &= jnp.tril(jnp.ones((S, S), bool))
    if cfg.sliding_window:
        i = jnp.arange(S)
        total &= (i[:, None] - i[None, :]) < cfg.sliding_window
    act = allow & total
    mx = jnp.max(jnp.where(act, s, -jnp.inf), -1, keepdims=True)
    mx = jnp.maximum(mx, -1e30)
    ex = jnp.where(act, jnp.exp(s - mx), 0.0)
    pruned = jnp.sum(total.astype(jnp.int32), -1) - jnp.sum(act.astype(jnp.int32), -1)
    denom = ex.sum(-1, keepdims=True) + pruned[None, None, None, :, None] * jnp.exp(-mx)
    p = (ex / denom).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return out.reshape(B, S, H, hd)


ARCHS = ["spion-lra", "qwen2-7b", "mixtral-8x7b"]


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("block", [16, 32])
def test_random_mask_matches_oracle(arch, block):
    cfg = get_config(arch)
    if cfg.sliding_window:
        cfg = cfg.replace(sliding_window=48)
    B, S, H, KV, hd = 2, 128, 4, 2, 16
    q, k, v = _qkv(jax.random.key(0), B, S, H, KV, hd)
    rng = np.random.default_rng(1)
    n = S // block
    mask = rng.random((n, n)) < 0.4
    np.fill_diagonal(mask, True)
    out = bcsr_attention(cfg, q, k, v, bcsr_from_blockmask(mask, block))
    ref = _oracle(cfg, q, k, v, mask, block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_mask_equals_dense(arch):
    """When P ≡ 1 the zero-correction vanishes and sparse == dense."""
    cfg = get_config(arch)
    B, S, H, KV, hd = 2, 64, 4, 4, 8
    q, k, v = _qkv(jax.random.key(2), B, S, H, KV, hd)
    out = bcsr_attention(cfg, q, k, v, full_bcsr(S, 16))
    ref = dense_attention(cfg, q, k, v, jnp.arange(S), jnp.arange(S))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_row_chunking_invariance():
    cfg = get_config("qwen2-7b")
    B, S, H, KV, hd = 1, 256, 4, 2, 16
    q, k, v = _qkv(jax.random.key(3), B, S, H, KV, hd)
    rng = np.random.default_rng(4)
    mask = rng.random((8, 8)) < 0.5
    np.fill_diagonal(mask, True)
    b = bcsr_from_blockmask(mask, 32)
    full = bcsr_attention(cfg, q, k, v, b, row_chunk=8)
    for rc in (1, 2, 4):
        out = bcsr_attention(cfg, q, k, v, b, row_chunk=rc)
        np.testing.assert_allclose(np.asarray(out), np.asarray(full), atol=1e-5)


def test_dense_attention_chunking_invariance():
    cfg = get_config("qwen2.5-14b")
    B, S, H, KV, hd = 2, 4096 // 8, 4, 2, 16  # S=512 with Sk -> chunked path
    q, k, v = _qkv(jax.random.key(5), B, S, H, KV, hd)
    from repro.models import attention as A
    orig = A.attn_q_chunk
    try:
        A.attn_q_chunk = lambda Sq, Sk: Sq       # force single chunk
        ref = dense_attention(cfg, q, k, v, jnp.arange(S), jnp.arange(S))
        A.attn_q_chunk = lambda Sq, Sk: 128      # force 4 chunks
        out = dense_attention(cfg, q, k, v, jnp.arange(S), jnp.arange(S))
    finally:
        A.attn_q_chunk = orig
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_opcount_formula_matches_paper():
    """§4.4: exact integers for L=4096, D=64 (AAN document retrieval)."""
    from benchmarks.opcount import dense_ops, sparse_ops
    L, D = 4096, 64
    assert dense_ops(L, D) == 4_328_255_488
    assert sparse_ops(1_677_721, L, D) == 432_585_778
    # ~10x reduction, as claimed
    assert 9.9 < dense_ops(L, D) / sparse_ops(1_677_721, L, D) < 10.1


def test_bcsr_attention_ops_counts_blocks():
    cfg = get_config("spion-lra").replace(head_dim=64, num_heads=1, num_kv_heads=1)
    L, blk = 512, 64
    n = L // blk
    mask = np.eye(n, dtype=bool)
    b = bcsr_from_blockmask(mask, blk)
    C = n * blk * blk
    assert bcsr_attention_ops(cfg, b) == 2 * C * (2 * 64 + 1) - L * (64 + 1)


# ---------------------------------------------------------------------------
# SparsityPlan column extents / halo bounds (DESIGN.md §10)
# ---------------------------------------------------------------------------

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.pattern import generate_pattern  # noqa: E402
from repro.core.sparse_attention import (build_sparsity_plan,  # noqa: E402
                                         pattern_col_extents)


def _spans(mask):
    """True per-row column span of a dense block mask: (left, right) max."""
    left = right = 0
    for r in range(mask.shape[0]):
        cols = np.nonzero(mask[r])[0]
        if len(cols):
            left = max(left, r - int(cols.min()))
            right = max(right, int(cols.max()) - r)
    return left, right


def _pattern(kind, n, seed, window):
    rng = np.random.default_rng(seed)
    if kind == "flood":
        # pooled-scores stand-in -> the real conv-flood-fill generator
        pooled = rng.random((n, n)) * np.exp(
            -np.abs(np.subtract.outer(np.arange(n), np.arange(n))) / 3.0)
        mask = generate_pattern(None, pooled=pooled, variant="cf",
                                block_size=1, alpha_quantile=0.8,
                                causal=False)
    elif kind == "sliding":
        i = np.arange(n)
        mask = (np.abs(np.subtract.outer(i, i)) <= window) & \
            (rng.random((n, n)) < 0.8)
        np.fill_diagonal(mask, True)
    else:  # causal random
        mask = np.tril(rng.random((n, n)) < 0.4)
        np.fill_diagonal(mask, True)
    return np.asarray(mask, bool)


@settings(deadline=None, max_examples=25)
@given(st.integers(0, 10_000), st.integers(4, 24),
       st.sampled_from(["flood", "sliding", "causal"]), st.integers(1, 4),
       st.integers(1, 3))
def test_plan_halo_upper_bounds_every_row_span(seed, n, kind, window, layers):
    """The host-computed per-layer col_extent (and the cross-layer halo) must
    upper-bound every BCSR row's true column span — the invariant the
    seq-parallel halo exchange relies on: a row-block never references a
    column-block outside [r - halo_left, r + halo_right]."""
    masks = [_pattern(kind, n, seed + i, window) for i in range(layers)]
    K = max(max(int(m.sum(axis=1).max()), 1) for m in masks)
    tabs = [bcsr_from_blockmask(m, 16, max_k=K) for m in masks]
    col = np.stack([np.asarray(t.col_idx) for t in tabs])
    nv = np.stack([np.asarray(t.nvalid) for t in tabs])
    ext_l, ext_r = pattern_col_extents(col, nv, ncb=n)
    plan = build_sparsity_plan(col, nv, 16, ncb=n)
    halo = plan.stats["halo"]
    assert list(halo) == [int(ext_l.max()), int(ext_r.max())]
    for li, m in enumerate(masks):
        span_l, span_r = _spans(m)
        assert ext_l[li] >= span_l, (kind, li, ext_l[li], span_l)
        assert ext_r[li] >= span_r, (kind, li, ext_r[li], span_r)
        assert halo[0] >= span_l and halo[1] >= span_r
        # and the bound is TIGHT for the raw tables (no mask config given)
        assert ext_l[li] == span_l and ext_r[li] == span_r
    assert plan.stats["col_extent_left"] == [int(x) for x in ext_l]
    assert plan.stats["col_extent_right"] == [int(x) for x in ext_r]
