"""Sequence-parallel sparse attention (DESIGN.md §10): the pattern-bounded
halo exchange — seq-axis choice rules, shard_map correctness vs the meshless
fused kernel and the jnp BCSR path, the loud too-wide fallback, the
train-step compile proof on a (seq, data) mesh, and the sharded-op cache
regression (mesh identity keyed by descriptor, not the live object).

All multi-device checks run in subprocesses with 4 fake host devices (jax
locks the device count at first init — same pattern as
tests/test_sharded_attention.py)."""
import pytest

from conftest import run_subprocess_case as _run_sub


# seq-axis fit rules: divisibility, single-neighbour halos, no ring-wrap
# aliasing; plus the seq-mesh constructors.
AXES_CODE = """
from repro.distributed.sharding import kernel_pspecs_from_axes, kernel_seq_axis
from repro.launch.mesh import make_production_mesh, make_seq_mesh
from jax.sharding import PartitionSpec as P

mesh = make_seq_mesh(2, 2)
assert dict(mesh.shape) == {"seq": 2, "data": 2}
# fits: nrb=8 over 2 shards (W=4), halo (2,1)
ax, why = kernel_seq_axis(mesh, 8, (2, 1))
assert ax == "seq", why
# no halo supplied (plan-less tables without extents)
ax, why = kernel_seq_axis(mesh, 8, None)
assert ax is None and "halo" in why
# nrb not divisible
ax, why = kernel_seq_axis(mesh, 7, (1, 1))
assert ax is None and "divisible" in why
# halo exceeds the shard width (single-neighbour exchange impossible)
ax, why = kernel_seq_axis(mesh, 8, (5, 0))
assert ax is None and "shard width" in why
# ring-wrap aliasing: h_l + h_r > (n-1) * W
ax, why = kernel_seq_axis(mesh, 8, (4, 3))
assert ax is None and "alias" in why
# no seq axis at all
from repro.launch.mesh import make_mesh
ax, why = kernel_seq_axis(make_mesh((2, 2), ("data", "model")), 8, (1, 1))
assert ax is None and "no 'seq' axis" in why
# pspec layout with a seq axis
q, kv, tab = kernel_pspecs_from_axes(("data",), None, "seq")
assert q == P(("data",), None, None, "seq", None)
assert kv == P(("data",), None, "seq", None)
assert tab == P()
print("OK")
"""


# seq-sharded fused forward must be BITWISE identical to the meshless fused
# kernel (each row-block streams the same tiles in the same order — the halo
# exchange only relocates the data), and fwd+grads must match the jnp BCSR
# path at the tests/test_kernels.py tolerances. Cases: encoder, causal,
# causal+sliding-window, GQA, plan-less (forward-built local transpose).
MATCH_CODE = """
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.core.sparse_attention import (BCSR, bcsr_attention,
                                         bcsr_from_blockmask,
                                         build_sparsity_plan)
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_seq_mesh
from repro.models.attention import resolve_sparse_kernel, spion_sparse_attention

mesh = make_seq_mesh(2, 2)
S, block, hd, B = 128, 16, 16, 4
n = S // block
rng = np.random.default_rng(0)

# (causal, sliding_window, H, KV, with_plan)
CASES = [(False, None, 4, 4, True),
         (True, None, 4, 4, False),
         (True, 48, 2, 2, True),
         (True, None, 4, 2, True),
         (False, None, 4, 2, False)]

for causal, sw, H, KV, with_plan in CASES:
    cfg = get_config("spion-lra").replace(
        causal=causal, sliding_window=sw, num_heads=H, num_kv_heads=KV,
        spion=dataclasses.replace(get_config("spion-lra").spion,
                                  block_size=block))
    # near-diagonal band pattern (extent <= 2): the flood-fill shape the
    # halo exchange targets
    mask = np.zeros((n, n), bool)
    for r in range(n):
        for c in range(max(r - 2, 0), min(r + 3, n)):
            mask[r, c] = rng.random() < 0.7
        mask[r, r] = True
    if causal:
        mask = np.tril(mask)
    b = bcsr_from_blockmask(mask, block)
    p = build_sparsity_plan(b.col_idx, b.nvalid, block, ncb=n)
    halo = tuple(p.stats["halo"])
    layer = {"col_idx": b.col_idx, "nvalid": b.nvalid, "block": block,
             "halo": halo}
    if with_plan:
        layer["row_idx"] = p.tables["row_idx"][0]
        layer["nvalid_t"] = p.tables["nvalid_t"][0]
    key = jax.random.key(hash((causal, H, KV)) % 1000)
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    gout = jax.random.normal(jax.random.fold_in(key, 3), (B, S, H, hd))

    def loss(q, k, v, impl):
        c = cfg.replace(spion=dataclasses.replace(cfg.spion, kernel=impl))
        return jnp.sum(spion_sparse_attention(c, q, k, v, layer) * gout)

    with mesh_context(mesh):
        assert resolve_sparse_kernel(cfg, B, KV, nrb=n, halo=halo) == "fused"
        o_sh = spion_sparse_attention(cfg, q, k, v, layer)
        g_sh = jax.grad(lambda *a: loss(*a, "auto"), argnums=(0, 1, 2))(q, k, v)
    local = {k_: v_ for k_, v_ in layer.items() if k_ != "halo"}
    o_local = spion_sparse_attention(
        cfg.replace(spion=dataclasses.replace(cfg.spion, kernel="fused")),
        q, k, v, local)
    o_jnp = bcsr_attention(cfg, q, k, v, BCSR(b.col_idx, b.nvalid, block, S))
    g_jnp = jax.grad(lambda *a: loss(*a, "jnp"), argnums=(0, 1, 2))(q, k, v)

    tag = f"causal={causal} sw={sw} H={H} KV={KV} plan={with_plan} halo={halo}"
    assert bool(jnp.all(o_sh == o_local)), f"seq-sharded fwd not bitwise: {tag}"
    np.testing.assert_allclose(np.asarray(o_sh), np.asarray(o_jnp),
                               atol=2e-5, err_msg=f"fwd vs jnp: {tag}")
    for name, a, w in zip("qkv", g_sh, g_jnp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w), atol=1e-3,
                                   err_msg=f"d{name} vs jnp: {tag}")
print("OK")
"""


# too-wide patterns: a global vertical stripe makes the halo exceed the
# shard width -> loud fallback to batch/KV sharding (warning, no ppermute),
# and a hard error when nothing else shards; "auto" resolves to jnp when the
# seq axis is the only candidate and the pattern is too wide.
FALLBACK_CODE = """
import dataclasses, warnings
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.core.sparse_attention import bcsr_from_blockmask, build_sparsity_plan
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_seq_mesh
from repro.kernels.sharded import sharded_fused_attention
from repro.models.attention import resolve_sparse_kernel

S, block, hd = 128, 16, 16
n = S // block
mask = np.zeros((n, n), bool)
np.fill_diagonal(mask, True)
mask[:, 0] = True                       # global-attention stripe
b = bcsr_from_blockmask(mask, block)
p = build_sparsity_plan(b.col_idx, b.nvalid, block, ncb=n)
halo = tuple(p.stats["halo"])
assert halo[0] == n - 1, halo           # stripe -> full left extent
col = jnp.maximum(b.col_idx, 0)
mesh = make_seq_mesh(2, 2)
B, KV, G = 4, 1, 1
q = jax.random.normal(jax.random.key(0), (B, KV, G, S, hd))
k = jax.random.normal(jax.random.key(1), (B, KV, S, hd))
v = jax.random.normal(jax.random.key(2), (B, KV, S, hd))

with mesh_context(mesh):
    # batch still shards -> warn + fall back, and the jaxpr must NOT carry
    # a halo exchange (no silent full-sequence ppermute)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        jaxpr = str(jax.make_jaxpr(lambda q, k, v: sharded_fused_attention(
            mesh, q, k, v, col, b.nvalid, block=block, interpret=True,
            halo=halo))(q, k, v))
    assert any("falls back to batch/KV" in str(x.message) for x in w), \
        [str(x.message) for x in w]
    assert "ppermute" not in jaxpr and "shard_map" in jaxpr
    # batch indivisible too -> actionable error, not silent replication
    q3 = jax.random.normal(jax.random.key(3), (3, KV, G, S, hd))
    k3 = jax.random.normal(jax.random.key(4), (3, KV, S, hd))
    try:
        sharded_fused_attention(mesh, q3, k3, k3, col, b.nvalid, block=block,
                                interpret=True, halo=halo)
        raise SystemExit("too-wide pattern with nothing else sharding must raise")
    except RuntimeError as e:
        assert "cannot seq-shard" in str(e) and "halo" in str(e), e
    # "auto" resolution: seq-only mesh + too-wide pattern -> jnp
    cfg = get_config("spion-lra")
    assert resolve_sparse_kernel(cfg, 3, 1, nrb=n, halo=halo) == "jnp"
    assert resolve_sparse_kernel(cfg, 3, 1, nrb=n, halo=(1, 0)) == "fused"
print("OK")
"""


# the sparse train step compiles and runs on a (seq=2, data=2) mesh with the
# halo exchange visible in the jaxpr (ppermute) and the lowered module
# (collective_permute + the shard_map manual-partitioning marker).
TRAIN_STEP_CODE = """
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_seq_mesh
from repro.launch.steps import make_train_step, spion_dryrun_tables
from repro.models.registry import build
from repro.optim import adamw_init

mesh = make_seq_mesh(2, 2)
L, B = 128, 4
cfg = get_config("spion-lra").reduced()
cfg = cfg.replace(num_heads=4, num_kv_heads=2, head_dim=16,
                  spion=dataclasses.replace(cfg.spion, block_size=16))
bundle = build(cfg)
params = jax.tree_util.tree_map(
    lambda x: x.astype(jnp.float32) if x.ndim >= 2 else x,
    bundle.init(jax.random.key(0)))
opt = adamw_init(params)
batch = {"tokens": jnp.zeros((B, L), jnp.int32),
         "labels": jnp.zeros((B, L), jnp.int32)}
tables = spion_dryrun_tables(cfg, L, max_extent=2)
assert tables["halo"] and max(tables["halo"]) <= 2, tables["halo"]
step = make_train_step(cfg, spion=True, sparse_kernel="auto",
                       halo=tables["halo"])
args = (params, opt, batch, jnp.int32(0), tables)
with mesh_context(mesh):
    jaxpr = str(jax.make_jaxpr(step)(*args))
    assert "shard_map" in jaxpr and "pallas_call" in jaxpr
    assert "ppermute" in jaxpr, "halo exchange missing from the jaxpr"
    lowered = jax.jit(step).lower(*args)
    hlo = lowered.as_text()
    assert "SPMDFullToShardShape" in hlo, "shard_map missing from HLO"
    assert "collective_permute" in hlo, "halo exchange missing from HLO"
    lowered.compile()
    p2, _, metrics = jax.jit(step)(*args)
    assert bool(jnp.isfinite(metrics["loss"]))
    delta = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x))), jax.tree_util.tree_map(
            jnp.subtract, p2, params), 0.0)
    assert delta > 0.0, "params must move through the seq-sharded step"
print("OK")
"""


# regression for the sharded-op cache: keyed on the mesh DESCRIPTOR, so
# re-creating an identical mesh (tests, serve restarts, remesh after fault
# recovery) reuses the entry instead of retaining every Mesh object forever.
CACHE_CODE = """
import numpy as np
import jax, jax.numpy as jnp
from repro.core.sparse_attention import bcsr_from_blockmask
from repro.distributed.sharding import mesh_context
from repro.launch.mesh import make_mesh
from repro.kernels import sharded
from repro.kernels.sharded import _op_cache_size, sharded_fused_attention

S, block, hd = 64, 16, 8
n = S // block
mask = np.eye(n, dtype=bool)
b = bcsr_from_blockmask(mask, block)
col = jnp.maximum(b.col_idx, 0)
q = jax.random.normal(jax.random.key(0), (4, 1, 1, S, hd))
k = jax.random.normal(jax.random.key(1), (4, 1, S, hd))

def call(mesh):
    with mesh_context(mesh):
        return sharded_fused_attention(mesh, q, k, k, col, b.nvalid,
                                       block=block, interpret=True)

m1 = make_mesh((2, 2), ("data", "model"))
call(m1)
n1 = _op_cache_size()
assert n1 >= 1
# an IDENTICAL mesh (fresh object) must hit the same cache entry
for _ in range(3):
    call(make_mesh((2, 2), ("data", "model")))
assert _op_cache_size() == n1, "identical meshes must not grow the op cache"
# a different mesh shape is a different entry
call(make_mesh((4,), ("data",)))
assert _op_cache_size() == n1 + 1
# and the cache is LRU-bounded as a churn backstop
sharded._OP_CACHE_MAX = n1 + 1
call(make_mesh((2, 2), ("data", "model")))   # reuse, no eviction needed
assert _op_cache_size() <= n1 + 1
print("OK")
"""


# a sparse dry-run cell must compile on a (seq, data) mesh (param sharding
# rules name 'model' unconditionally — sanitize_spec drops mesh-absent
# axes) and record the seq-sharding decision with its reason.
DRYRUN_CELL_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, tempfile
import jax
jax.devices()   # lock the 4-device count before dryrun's 512 flag could bite
from repro.configs.base import SHAPES, ShapeSpec
from repro.configs import get_config
from repro.launch import dryrun
from repro.launch.mesh import make_seq_mesh

SHAPES["tiny_train"] = ShapeSpec("tiny_train", 128, 4, "train")
cfg = get_config("spion-lra").reduced()
cfg = cfg.replace(num_heads=4, num_kv_heads=2, head_dim=16,
                  spion=dataclasses.replace(cfg.spion, block_size=16))
with tempfile.TemporaryDirectory() as d:
    rec = dryrun.run_cell("spion-lra", "tiny_train", False, "sparse", d,
                          verbose=False, cfg_override=cfg, skip_costs=True,
                          mesh_override=make_seq_mesh(2, 2))
assert rec["status"] == "ok", rec
assert rec["sparse_kernel"] == "fused", rec
seq = rec["seq_sharded"]
# the default dryrun pattern has global verticals -> too wide, recorded so
assert seq["active"] is False and seq["halo"] and "halo" in seq["detail"], seq
print("OK")
"""


@pytest.mark.parametrize("code", [AXES_CODE, MATCH_CODE, FALLBACK_CODE,
                                  TRAIN_STEP_CODE, CACHE_CODE,
                                  DRYRUN_CELL_CODE],
                         ids=["axes", "match", "fallback", "train_step",
                              "cache", "dryrun_cell"])
def test_seq_parallel_subprocess(code):
    assert "OK" in _run_sub(code)
