"""Serving: continuous-batching engine, fused prefill, sparse decode.

Covers the DESIGN.md §11 invariants:
  - sparse decode == dense decode (kernel tolerances) where the pattern
    covers every visible position, and == the sparse prefill row (Alg. 6
    zero-correction parity) for ANY pattern;
  - fused prefill -> decode matches token-by-token teacher forcing;
  - mixed prompt lengths leave no cross-slot contamination (bitwise cache
    check against isolated runs);
  - the sliding-window ring-buffer path serves prompts longer than the
    cache;
  - continuous batching: more requests than slots, admission mid-decode,
    slot reclamation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.attention_exec import SparseAttentionExec
from repro.core.sparse_attention import sparse_decode_attention
from repro.launch.serve import Request, ServeEngine
from repro.launch.steps import causal_band_tables
from repro.models.attention import decode_attention
from repro.models.registry import build


def _cfg():
    return get_config("qwen2-7b").reduced().replace(remat=False)


def _reference_tokens(b, params, prompt, max_new, cache_len):
    """Token-by-token teacher-forced prefill + greedy decode, B=1."""
    cache = b.init_cache(1, cache_len)
    nxt = None
    for t, tok in enumerate(prompt):
        logits, cache = b.decode_step(params, cache,
                                      jnp.asarray([[int(tok)]], jnp.int32),
                                      jnp.int32(t))
        nxt = int(jnp.argmax(logits, -1)[0])
    out = [nxt]
    for j in range(max_new - 1):
        logits, cache = b.decode_step(params, cache,
                                      jnp.asarray([[out[-1]]], jnp.int32),
                                      jnp.int32(len(prompt) + j))
        out.append(int(jnp.argmax(logits, -1)[0]))
    return out


def _full_causal_tables(layers, nrb):
    """Every row-block lists every causal column block (full coverage) —
    the shared stand-in builder (launch/steps.causal_band_tables), as jnp."""
    t = causal_band_tables(layers, nrb)
    return {k: jnp.asarray(v) for k, v in t.items()}


def _banded_tables(layers, nrb, width=2):
    """Causal band: each row-block lists its last `width` blocks."""
    t = causal_band_tables(layers, nrb, width=width)
    return {k: jnp.asarray(v) for k, v in t.items()}


# ---------------------------------------------------------------------------
# engine basics (greedy parity, timing, continuous batching)
# ---------------------------------------------------------------------------

def test_serve_engine_greedy_matches_reference():
    cfg = _cfg()
    b = build(cfg)
    params = b.init(jax.random.key(0))
    prompts = [np.array([5, 9, 2], np.int32), np.array([7, 1, 1], np.int32)]

    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    reqs = [Request(rid=i, prompt=p, max_new=4) for i, p in enumerate(prompts)]
    eng.run(reqs)

    for i, p in enumerate(prompts):
        want = _reference_tokens(b, params, p, 4, 32)
        assert reqs[i].out == want, (i, reqs[i].out, want)


def test_serve_engine_timing_fields():
    cfg = _cfg()
    b = build(cfg)
    params = b.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=1, max_len=16)
    r = Request(rid=0, prompt=np.array([1, 2], np.int32), max_new=2)
    eng.run([r])
    assert r.done and len(r.out) == 2
    assert r.t_done >= r.t_first >= r.t_submit > 0


def test_continuous_batching_more_requests_than_slots():
    """5 requests through 2 slots: admission mid-decode, slot reclamation,
    per-request outputs identical to isolated runs despite mixed prompt
    lengths and mixed max_new."""
    cfg = _cfg()
    b = build(cfg)
    params = b.init(jax.random.key(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (2, 5, 3, 7, 4)]
    max_news = [3, 1, 5, 2, 4]

    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    reqs = [Request(rid=i, prompt=p, max_new=m)
            for i, (p, m) in enumerate(zip(prompts, max_news))]
    eng.run(reqs)

    assert all(r.done and len(r.out) == m for r, m in zip(reqs, max_news))
    assert not eng.waiting and all(a is None for a in eng.active)
    for r, p, m in zip(reqs, prompts, max_news):
        assert r.out == _reference_tokens(b, params, p, m, 32), r.rid


@pytest.mark.parametrize("paged", [False, True])
def test_mixed_prompt_lengths_bitwise_clean_caches(paged):
    """Each slot's written cache region after a mixed-length batched run is
    BITWISE identical to an isolated run of the same request — per-slot
    positions + per-request prefill make cross-slot pollution structurally
    impossible, for both the contiguous cache and the paged pool (whose
    slot view is gathered back through the page table by slot_kv)."""
    cfg = _cfg()
    b = build(cfg)
    params = b.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    lens = (2, 5, 3, 7)
    max_new = 4
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in lens]

    eng = ServeEngine(cfg, params, slots=4, max_len=32, paged=paged)
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    eng.run(reqs)

    for i, p in enumerate(prompts):
        solo = ServeEngine(cfg, params, slots=4, max_len=32, paged=paged)
        rs = Request(rid=i, prompt=p.copy(), max_new=max_new)
        solo.run([rs])
        assert reqs[i].out == rs.out, i
        # written region: prompt + fed generated tokens (the last generated
        # token is never fed back, so P + max_new - 1 positions)
        n = len(p) + max_new - 1
        ka, va = eng.slot_kv(i, n)
        kw, vw = solo.slot_kv(0, n)
        assert np.array_equal(ka, kw), i
        assert np.array_equal(va, vw), i


# ---------------------------------------------------------------------------
# fused prefill
# ---------------------------------------------------------------------------

def test_fused_prefill_matches_stepwise_decode():
    """prefill_kv's logits and K/V match token-by-token teacher forcing via
    decode_step at every prompt position (the engine's two prefill paths
    agree)."""
    cfg = _cfg()
    b = build(cfg)
    params = b.init(jax.random.key(0))
    S = 8
    toks = jax.random.randint(jax.random.key(1), (1, S), 0, cfg.vocab_size)

    logits_f, ks, vs = b.prefill_kv(params, {"tokens": toks})
    cache = b.init_cache(1, S)
    for t in range(S):
        logits_t, cache = b.decode_step(params, cache, toks[:, t:t + 1],
                                        jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits_t, np.float32),
            np.asarray(logits_f[:, t], np.float32), atol=5e-2)
    np.testing.assert_allclose(
        np.asarray(ks.astype(jnp.float32)),
        np.asarray(cache["k"].astype(jnp.float32)), atol=5e-2)
    np.testing.assert_allclose(
        np.asarray(vs.astype(jnp.float32)),
        np.asarray(cache["v"].astype(jnp.float32)), atol=5e-2)


# ---------------------------------------------------------------------------
# sparse decode
# ---------------------------------------------------------------------------

def test_sparse_decode_matches_dense_where_covered():
    """With tables covering every causal position, the pattern-bounded
    gather reduces to dense decode at kernel-test tolerances — including
    per-row vector positions."""
    cfg = _cfg()
    B, S, H, KV, hd, block = 2, 32, 4, 4, 16, 4
    keys = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(keys[0], (B, 1, H, hd), jnp.float32)
    kc = jax.random.normal(keys[1], (B, S, KV, hd), jnp.float32)
    vc = jax.random.normal(keys[2], (B, S, KV, hd), jnp.float32)
    tabs = _full_causal_tables(1, S // block)
    col, nval = tabs["col_idx"][0], tabs["nvalid"][0]

    for pos in (0, 5, S - 1):
        want = decode_attention(cfg, q, kc, vc, jnp.int32(pos))
        got = sparse_decode_attention(cfg, q, kc, vc, jnp.int32(pos),
                                      col, nval, block=block)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)

    # vector positions: each row at its own offset == scalar runs per row
    posv = jnp.asarray([5, S - 1], jnp.int32)
    got = sparse_decode_attention(cfg, q, kc, vc, posv, col, nval, block=block)
    for i, p in enumerate((5, S - 1)):
        want = decode_attention(cfg, q[i:i + 1], kc[i:i + 1], vc[i:i + 1],
                                jnp.int32(p))
        np.testing.assert_allclose(np.asarray(got[i:i + 1]),
                                   np.asarray(want), atol=2e-5)


def test_sparse_prefill_decode_parity():
    """Alg. 6 parity for a PARTIAL pattern: teacher-forced sparse decode
    reproduces the sparse forward's row logits — both count pruned causal
    positions as exp(-max) in the denominator, so decode matches prefill
    even where the pattern does NOT cover."""
    cfg = _cfg()
    b = build(cfg)
    params = b.init(jax.random.key(0))
    S, block = 16, 4
    tabs = _banded_tables(cfg.num_layers, S // block, width=2)
    ex = SparseAttentionExec(tabs, block=block, phase="prefill")
    toks = jax.random.randint(jax.random.key(1), (2, S), 0, cfg.vocab_size)

    logits_f, _ = b.forward(params, {"tokens": toks}, spion=ex)
    cache = b.init_cache(2, S)
    for t in range(S):
        logits_t, cache = b.decode_step(params, cache, toks[:, t:t + 1],
                                        jnp.int32(t), spion=ex)
        np.testing.assert_allclose(
            np.asarray(logits_t, np.float32),
            np.asarray(logits_f[:, t], np.float32), atol=5e-2,
            err_msg=f"position {t}")


def test_sparse_engine_matches_dense_with_covering_pattern():
    """End-to-end sparse serving: with a fully-covering plan the sparse
    engine generates the same tokens as the dense engine, and the coverage
    guard rejects requests past the plan. The plan covers 64 positions but
    the cache holds 32, so the causal sparse prefill runs on SLICED row
    tables (O(prompt bucket), not O(coverage))."""
    cfg = _cfg()
    b = build(cfg)
    params = b.init(jax.random.key(0))
    block, max_len = 8, 32
    tabs = dict(_full_causal_tables(cfg.num_layers, 2 * max_len // block),
                block=block)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (3, 6)]

    dense = ServeEngine(cfg, params, slots=2, max_len=max_len)
    sparse = ServeEngine(cfg, params, slots=2, max_len=max_len, spion=tabs)
    dreqs = [Request(rid=i, prompt=p, max_new=4) for i, p in enumerate(prompts)]
    sreqs = [Request(rid=i, prompt=p.copy(), max_new=4)
             for i, p in enumerate(prompts)]
    dense.run(dreqs)
    sparse.run(sreqs)
    for d, s in zip(dreqs, sreqs):
        assert d.out == s.out, (d.rid, d.out, s.out)

    import pytest
    with pytest.raises(ValueError, match="exceeds"):
        sparse.submit(Request(rid=9, prompt=np.arange(30, dtype=np.int32),
                              max_new=4))
    # the coverage guard specifically: a bigger ring cache, same small plan
    ring_cfg = get_config("mixtral-8x7b").reduced().replace(remat=False)
    ring_params = build(ring_cfg).init(jax.random.key(0))
    small = ServeEngine(ring_cfg, ring_params, slots=1, max_len=64,
                        spion=dict(_full_causal_tables(1, 2), block=8))
    with pytest.raises(ValueError, match="coverage"):
        small.submit(Request(rid=9, prompt=np.arange(30, dtype=np.int32),
                             max_new=4))


# ---------------------------------------------------------------------------
# sliding-window ring buffer
# ---------------------------------------------------------------------------

def test_sliding_window_ring_engine():
    """A sliding-window arch serves a prompt LONGER than its ring cache:
    the fused prefill's ring insert reproduces the decode-time ring layout
    and generation matches the stepwise reference. The cache is sized to
    the window (a ring SMALLER than the window is lossier than the fused
    full-window prefill, so the two prefill paths only agree at
    cache_len >= sliding_window)."""
    cfg = get_config("mixtral-8x7b").reduced().replace(remat=False)
    assert cfg.sliding_window
    b = build(cfg)
    params = b.init(jax.random.key(0))
    cache_len = cfg.sliding_window            # 64; prompt 70 wraps the ring
    prompt = np.asarray(
        jax.random.randint(jax.random.key(2), (70,), 0, cfg.vocab_size),
        np.int32)

    eng = ServeEngine(cfg, params, slots=2, max_len=cache_len)
    r = Request(rid=0, prompt=prompt, max_new=4)
    eng.run([r])
    want = _reference_tokens(b, params, prompt, 4, cache_len)
    assert r.out == want, (r.out, want)


def test_hybrid_stepwise_prefill_engine():
    """Families without a plain KV cache (hybrid: mamba/conv states plus the
    shared attention block) serve through the stepwise per-request prefill —
    a FRESH B=1 cache teacher-forced and written into the slot, so stale
    slot state can never leak into a new request — and then join the same
    batched per-slot-position decode."""
    cfg = get_config("zamba2-1.2b").reduced().replace(remat=False)
    assert cfg.family == "hybrid"
    b = build(cfg)
    params = b.init(jax.random.key(0))
    prompts = [np.array([3, 1, 4, 1, 5], np.int32),
               np.array([2, 7], np.int32)]
    eng = ServeEngine(cfg, params, slots=2, max_len=16)
    assert not eng._can_fuse
    reqs = [Request(rid=i, prompt=p, max_new=3) for i, p in enumerate(prompts)]
    eng.run(reqs)
    for i, p in enumerate(prompts):
        want = _reference_tokens(b, params, p, 3, 16)
        assert reqs[i].out == want, (i, reqs[i].out, want)


def test_sparse_ring_decode_masks_rotated_out_positions():
    """Sparse decode on a ring cache: blocks that rotated out contribute
    nothing — parity with dense ring decode under a covering pattern."""
    cfg = get_config("mixtral-8x7b").reduced().replace(remat=False)
    from repro.models.attention import ring_kpos
    B, S, H, KV, hd, block = 1, 16, 4, 4, 16, 4
    keys = jax.random.split(jax.random.key(4), 3)
    q = jax.random.normal(keys[0], (B, 1, H, hd), jnp.float32)
    kc = jax.random.normal(keys[1], (B, S, KV, hd), jnp.float32)
    vc = jax.random.normal(keys[2], (B, S, KV, hd), jnp.float32)
    pos = 21                      # ring has wrapped (holds positions 6..21)
    nrb = 8                       # tables cover 32 positions > ring length
    tabs = _full_causal_tables(1, nrb)
    want = decode_attention(cfg, q, kc, vc, jnp.int32(pos),
                            kpos=ring_kpos(jnp.int32(pos), S))
    got = sparse_decode_attention(cfg, q, kc, vc, jnp.int32(pos),
                                  tabs["col_idx"][0], tabs["nvalid"][0],
                                  block=block, ring=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
