"""Serving engine: batched greedy decode matches a hand-rolled reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.serve import Request, ServeEngine
from repro.models.registry import build


def test_serve_engine_greedy_matches_reference():
    cfg = get_config("qwen2-7b").reduced().replace(remat=False)
    b = build(cfg)
    params = b.init(jax.random.key(0))
    prompts = [np.array([5, 9, 2], np.int32), np.array([7, 1, 1], np.int32)]

    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    reqs = [Request(rid=i, prompt=p, max_new=4) for i, p in enumerate(prompts)]
    eng.run(reqs)

    # reference: single-request decode loops
    for i, p in enumerate(prompts):
        cache = b.init_cache(1, 32)
        nxt = None
        for t, tok in enumerate(p):
            logits, cache = b.decode_step(params, cache,
                                          jnp.asarray([[tok]]), jnp.int32(t))
            nxt = int(jnp.argmax(logits, -1)[0])
        out = []
        for j in range(4):
            out.append(nxt)
            logits, cache = b.decode_step(params, cache,
                                          jnp.asarray([[nxt]]), jnp.int32(len(p) + j))
            nxt = int(jnp.argmax(logits, -1)[0])
        assert reqs[i].out == out, (i, reqs[i].out, out)


def test_serve_engine_timing_fields():
    cfg = get_config("qwen2-7b").reduced().replace(remat=False)
    b = build(cfg)
    params = b.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=1, max_len=16)
    r = Request(rid=0, prompt=np.array([1, 2], np.int32), max_new=2)
    eng.run([r])
    assert r.done and len(r.out) == 2
    assert r.t_done >= r.t_first >= r.t_submit > 0
