"""Compiled-lane autotuner tests (DESIGN.md §15).

Holds the two contracts the cache lives by:

  1. A KernelConfig may only ever change SPEED — tuned and default outputs
     (forward AND gradients) are bitwise identical at every pipeline depth.
  2. The on-disk cache degrades loudly, never fatally: corrupted, stale, or
     unknown-field entries warn and fall back to the default config.

Plus the integration seam: SparseAttentionExec consults the cache at
construction (concrete tables only — tracer tables skip the lookup), and
the tuned config rides its static pytree aux into the jitted step.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.attention_exec import SparseAttentionExec
from repro.core.sparse_attention import bcsr_from_blockmask
from repro.kernels import autotune
from repro.kernels.block_sparse_attn import fused_block_sparse_attention
from repro.kernels.dispatch import DEFAULT_CONFIG, KernelConfig


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    """Every test gets a private cache dir; never touch ~/.cache."""
    monkeypatch.setenv("SPION_AUTOTUNE_DIR", str(tmp_path / "autotune"))
    monkeypatch.delenv("SPION_AUTOTUNE", raising=False)
    yield


def _tables(rng, n=8, block=32, density=0.5):
    mask = rng.random((n, n)) < density
    np.fill_diagonal(mask, True)
    b = bcsr_from_blockmask(mask, block)
    return {"col_idx": b.col_idx, "nvalid": b.nvalid}, b


def _qkv(b, hd=16, N=2, G=1):
    S = b.col_idx.shape[0] * b.block
    q = jax.random.normal(jax.random.key(0), (N, G, S, hd))
    k = jax.random.normal(jax.random.key(1), (N, S, hd))
    v = jax.random.normal(jax.random.key(2), (N, S, hd))
    return q, k, v


def _run(b, config, q, k, v):
    col = jnp.maximum(b.col_idx, 0)
    return fused_block_sparse_attention(q, k, v, col, b.nvalid,
                                        block=b.block, interpret=True,
                                        config=config)


# ---------------------------------------------------------------------------
# contract 1: configs are scheduling-only — bitwise identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth", [1, 3, 5])
def test_tuned_vs_default_bitwise(depth, rng):
    """Any pipeline depth gives bitwise-identical forward AND grads vs the
    default config — depth only moves DMA issue distance, never math."""
    tables, b = _tables(rng)
    q, k, v = _qkv(b)

    def loss(config, q, k, v):
        return jnp.sum(_run(b, config, q, k, v) ** 2)

    base = _run(b, DEFAULT_CONFIG, q, k, v)
    out = _run(b, KernelConfig(depth=depth), q, k, v)
    assert np.array_equal(np.asarray(out), np.asarray(base))
    gbase = jax.grad(loss, argnums=(1, 2, 3))(DEFAULT_CONFIG, q, k, v)
    gout = jax.grad(loss, argnums=(1, 2, 3))(KernelConfig(depth=depth),
                                             q, k, v)
    for ga, gb in zip(gout, gbase):
        assert np.array_equal(np.asarray(ga), np.asarray(gb))


def test_config_json_roundtrip():
    cfg = KernelConfig(depth=3,
                       dimension_semantics=("arbitrary",) * 3, num_warps=4)
    d = cfg.to_json()
    json.dumps(d)  # must be serialisable as-is
    assert KernelConfig.from_json(d) == cfg
    assert KernelConfig.from_json(KernelConfig().to_json()) == DEFAULT_CONFIG


def test_config_from_json_rejects_bad_entries():
    with pytest.raises(ValueError, match="unknown"):
        KernelConfig.from_json({"depth": 2, "bogus": 1})
    with pytest.raises(ValueError, match="depth"):
        KernelConfig.from_json({"depth": 0})
    with pytest.raises(ValueError, match="depth"):
        KernelConfig.from_json({"depth": "two"})


# ---------------------------------------------------------------------------
# contract 2: cache IO — roundtrip, loud fallback
# ---------------------------------------------------------------------------

def test_store_lookup_roundtrip(rng):
    tables, b = _tables(rng)
    assert autotune.lookup(tables, b.block) is None  # cold miss
    cfg = KernelConfig(depth=3)
    path = autotune.store(tables, b.block, cfg, best_us=12.5, swept=3)
    assert os.path.exists(path)
    assert path.startswith(autotune.cache_dir())
    assert autotune.lookup(tables, b.block) == cfg
    # a different dtype is a different key
    assert autotune.lookup(tables, b.block, dtype=jnp.bfloat16) is None


def test_corrupted_entry_warns_and_falls_back(rng):
    tables, b = _tables(rng)
    path = autotune.store(tables, b.block, KernelConfig(depth=3))
    with open(path, "w") as f:
        f.write("not json {{{")
    with pytest.warns(UserWarning, match="unusable cache entry"):
        assert autotune.lookup(tables, b.block) is None


def test_stale_version_warns_and_falls_back(rng):
    tables, b = _tables(rng)
    path = autotune.store(tables, b.block, KernelConfig(depth=3))
    with open(path) as f:
        entry = json.load(f)
    entry["version"] = 0
    with open(path, "w") as f:
        json.dump(entry, f)
    with pytest.warns(UserWarning, match="stale"):
        assert autotune.lookup(tables, b.block) is None


def test_unknown_config_field_warns_and_falls_back(rng):
    tables, b = _tables(rng)
    path = autotune.store(tables, b.block, KernelConfig(depth=3))
    with open(path) as f:
        entry = json.load(f)
    entry["config"]["from_the_future"] = 7
    with open(path, "w") as f:
        json.dump(entry, f)
    with pytest.warns(UserWarning, match="unknown KernelConfig fields"):
        assert autotune.lookup(tables, b.block) is None


def test_env_disable_skips_cache(rng, monkeypatch):
    tables, b = _tables(rng)
    autotune.store(tables, b.block, KernelConfig(depth=3))
    monkeypatch.setenv("SPION_AUTOTUNE", "0")
    assert not autotune.enabled()
    assert autotune.lookup(tables, b.block) is None


def test_digest_distinguishes_pattern_and_block(rng):
    tables, b = _tables(rng)
    other, _ = _tables(rng, density=0.9)
    d1 = autotune.pattern_digest(tables, b.block)
    assert d1 == autotune.pattern_digest(tables, b.block)  # deterministic
    assert d1 != autotune.pattern_digest(other, b.block)
    assert d1 != autotune.pattern_digest(tables, b.block * 2)
    # transposed tables extend the digest (plan-built vs bare pattern)
    extended = dict(tables, row_idx=np.zeros((4, 4), np.int32),
                    nvalid_t=np.ones((4,), np.int32))
    assert d1 != autotune.pattern_digest(extended, b.block)


def test_candidate_sets_are_bounded():
    for backend, expect in [("interpret", 3), ("tpu", 6), ("gpu", 8)]:
        cands = autotune.candidates(backend)
        assert len(cands) == expect, backend
        assert all(isinstance(c, KernelConfig) and c.depth >= 1
                   for c in cands)


# ---------------------------------------------------------------------------
# the full lane: tune -> cache -> exec dispatch
# ---------------------------------------------------------------------------

def test_tune_end_to_end(rng):
    tables, b = _tables(rng)
    best, report = autotune.tune(tables, b.block, head_dim=16, reps=1,
                                 interpret=True)
    # every candidate was bitwise-checked against the default and passed
    assert len(report) >= len(autotune.candidates())
    assert all(r["bitwise"] for r in report)
    assert autotune.lookup(tables, b.block) == best
    q, k, v = _qkv(b)
    assert np.array_equal(np.asarray(_run(b, best, q, k, v)),
                          np.asarray(_run(b, DEFAULT_CONFIG, q, k, v)))


def test_exec_construction_consults_cache(rng):
    tables, b = _tables(rng)
    tuned = KernelConfig(depth=1)
    autotune.store(tables, b.block, tuned)
    ex = SparseAttentionExec(tables, block=b.block, kernel="fused")
    assert ex.kernel_config == tuned
    # the config is STATIC: it rides the pytree aux through jit untouched
    leaves, aux = jax.tree_util.tree_flatten(ex)
    rebuilt = jax.tree_util.tree_unflatten(aux, leaves)
    assert rebuilt.kernel_config == tuned
    # an explicit config wins over the cache
    ex2 = SparseAttentionExec(tables, block=b.block,
                              kernel_config=KernelConfig(depth=5))
    assert ex2.kernel_config == KernelConfig(depth=5)


def test_exec_attend_tuned_matches_default(rng, monkeypatch):
    cfg = get_config("spion-lra")
    tables, b = _tables(rng)
    autotune.store(tables, b.block, KernelConfig(depth=3))
    ex_tuned = SparseAttentionExec(tables, block=b.block, kernel="fused")
    assert ex_tuned.kernel_config == KernelConfig(depth=3)
    monkeypatch.setenv("SPION_AUTOTUNE", "0")
    ex_plain = SparseAttentionExec(tables, block=b.block, kernel="fused")
    assert ex_plain.kernel_config is None
    S, hd = ex_tuned.coverage, 16
    q = jax.random.normal(jax.random.key(0), (2, S, 2, hd))
    kv = jax.random.normal(jax.random.key(1), (2, S, 2, hd))
    layer = {k: jnp.asarray(v) for k, v in tables.items()}
    out_t = ex_tuned.attend(cfg, q, kv, kv, layer)
    out_p = ex_plain.attend(cfg, q, kv, kv, layer)
    assert np.array_equal(np.asarray(out_t), np.asarray(out_p))


def test_exec_construction_under_jit_is_tracer_safe(rng):
    """Tables that are tracers (the legacy dict payload crossing a jit
    boundary) must skip the cache lookup, not crash hashing a tracer."""
    tables, b = _tables(rng)
    autotune.store(tables, b.block, KernelConfig(depth=3))

    @jax.jit
    def build(col, nvalid):
        ex = SparseAttentionExec({"col_idx": col, "nvalid": nvalid},
                                 block=b.block)
        assert ex.kernel_config is None  # trace-time: lookup skipped
        return ex.tables["col_idx"].sum()

    build(jnp.asarray(tables["col_idx"]), jnp.asarray(tables["nvalid"]))


def test_describe():
    assert autotune.describe(None) == "default"
    s = autotune.describe(KernelConfig(depth=3, num_warps=8))
    assert "depth=3" in s and "num_warps=8" in s
